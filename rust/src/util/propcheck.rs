//! Property-based testing helper (proptest is unavailable offline).
//!
//! A `Gen` produces random values from a seeded [`Pcg64`]; [`check`] runs a
//! property over N generated cases and, on failure, performs greedy
//! shrinking via the value's [`Shrink`] implementation before reporting the
//! minimal counterexample.  Used by the coordinator/RL invariant tests
//! (DESIGN.md §7).

use super::rng::Pcg64;

/// Random value generator.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
}

/// Shrinking: yield "smaller" candidate values, nearest-first.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(self / 2);
            out.push(if *self > 0 { self - 1 } else { self + 1 });
            if *self < 0 {
                out.push(-self);
            }
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            let mut minus_last = self.clone();
            minus_last.pop();
            out.push(minus_last);
            // shrink one element at a time (first few positions)
            for i in 0..self.len().min(4) {
                for cand in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

// ---- ready-made generators -------------------------------------------------

pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        rng.range_i64(self.0 as i64, self.1 as i64) as usize
    }
}

pub struct F64In(pub f64, pub f64);

impl Gen for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        self.0 + rng.f64() * (self.1 - self.0)
    }
}

pub struct VecOf<G: Gen>(pub G, pub usize, pub usize);

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        let n = rng.range_i64(self.1 as i64, self.2 as i64) as usize;
        (0..n).map(|_| self.0.generate(rng)).collect()
    }
}

pub struct Pair<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum CheckResult<V> {
    Pass { cases: usize },
    Fail { original: V, minimal: V, shrinks: usize },
}

/// Run `prop` over `cases` random values; shrink on first failure.
pub fn check<G>(seed: u64, cases: usize, gen: &G,
                prop: impl Fn(&G::Value) -> bool) -> CheckResult<G::Value>
where
    G: Gen,
    G::Value: Shrink,
{
    let mut rng = Pcg64::new(seed);
    for _ in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            // greedy shrink
            let original = v.clone();
            let mut current = v;
            let mut shrinks = 0;
            'outer: loop {
                for cand in current.shrink() {
                    if !prop(&cand) {
                        current = cand;
                        shrinks += 1;
                        if shrinks > 1000 {
                            break 'outer;
                        }
                        continue 'outer;
                    }
                }
                break;
            }
            return CheckResult::Fail { original, minimal: current, shrinks };
        }
    }
    CheckResult::Pass { cases }
}

/// Assert helper for tests: panics with the minimal counterexample.
pub fn assert_prop<G>(name: &str, seed: u64, cases: usize, gen: &G,
                      prop: impl Fn(&G::Value) -> bool)
where
    G: Gen,
    G::Value: Shrink,
{
    match check(seed, cases, gen, prop) {
        CheckResult::Pass { .. } => {}
        CheckResult::Fail { original, minimal, shrinks } => panic!(
            "property {name} failed\n  original: {original:?}\n  minimal \
             (after {shrinks} shrinks): {minimal:?}"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        assert_prop("sum-nonneg", 1, 200, &VecOf(UsizeIn(0, 100), 0, 20),
                    |v| v.iter().sum::<usize>() < usize::MAX);
    }

    #[test]
    fn failing_property_shrinks() {
        // fails whenever the vec contains an element >= 10; minimal case is
        // a short vector
        let r = check(3, 500, &VecOf(UsizeIn(0, 100), 0, 20), |v| {
            v.iter().all(|&x| x < 10)
        });
        match r {
            CheckResult::Fail { minimal, .. } => {
                assert!(minimal.len() <= 2, "minimal={minimal:?}");
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn f64_gen_in_range() {
        let mut rng = Pcg64::new(4);
        let g = F64In(-2.0, 3.0);
        for _ in 0..1000 {
            let x = g.generate(&mut rng);
            assert!((-2.0..3.0).contains(&x));
        }
    }
}
