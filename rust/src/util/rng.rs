//! Deterministic, seedable PCG64 (DXSM) random number generator.
//!
//! The offline build environment ships no `rand` crate, so the coordinator
//! carries its own generator.  PCG64-DXSM is the numpy default generator;
//! this implementation is self-contained and deterministic across platforms,
//! which the reproduction relies on (every experiment is seeded and the
//! manifest records the seeds).

/// PCG64-DXSM: 128-bit LCG state with a double-xor-shift-multiply output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0xda94_2042_e4dd_58b5;

impl Pcg64 {
    /// Seed with an arbitrary u64; a SplitMix64 expansion fills the state.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let inc = (((sm.next() as u128) << 64) | sm.next() as u128) | 1;
        let mut rng = Pcg64 { state: state.wrapping_add(inc), inc };
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-worker / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ tag.rotate_left(17);
        let b = self.next_u64().wrapping_add(0x9e37_79b9_7f4a_7c15 ^ tag);
        Pcg64::new(a ^ b.rotate_left(31))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output on the pre-advance state (cheap-multiplier variant)
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(PCG_MULT as u64);
        hi ^= hi >> 48;
        hi = hi.wrapping_mul(lo);
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        hi
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Capture the full generator state as a `(state, inc)` pair for
    /// checkpointing.  [`Pcg64::restore`] with these values yields a
    /// generator whose output stream continues bit-identically from this
    /// exact position — the contract the crash-safe resume guarantee in
    /// [`crate::rl::checkpoint`] rests on.
    #[inline]
    pub fn snapshot(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::snapshot`] pair.  No
    /// re-seeding, no warm-up draw: the fields are restored verbatim, so
    /// the first `next_u64` after restore equals the first `next_u64` the
    /// snapshotted generator would have produced.
    #[inline]
    pub fn restore(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }
}

/// Per-member sampling-seed derivation: member `i` of a rollout group with
/// base seed `s` decodes with the stream seeded by `member_seed(s, i)`.
///
/// This is THE single definition every rollout path must use — the service
/// ([`RolloutService::submit_group`](crate::coordinator::RolloutService::submit_group))
/// and any bench/test that reconstructs a group's member streams by hand.
/// Before extraction the SplitMix-style wrap lived inline in the service,
/// where a second implementation could silently drift and break the
/// fused-vs-service parity guarantee (greedy is seed-independent, but any
/// sampled-parity comparison dies the moment two paths disagree here).
/// Values are pinned by `member_seed_pinned` below; do not change the
/// constant without a parity migration.
#[inline]
pub fn member_seed(base: u64, member: usize) -> u64 {
    base.wrapping_add(member as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// SplitMix64 — used only to expand seeds.
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    /// Pin the exact member-seed values: sampled-rollout reproducibility
    /// across the service and any path reconstructing member streams rests
    /// on these bits never changing.
    #[test]
    fn member_seed_pinned() {
        assert_eq!(member_seed(0, 0), 0);
        assert_eq!(member_seed(0, 1), 0x9e37_79b9_7f4a_7c15);
        assert_eq!(member_seed(0xFEED, 2), 0xc090_b079_bda6_ad9b);
        assert_eq!(member_seed(0x5eed, 7), 0x2b92_218a_ac8d_fa04);
        assert_eq!(member_seed((1u64 << 63) + 12345, 3),
                   0xfbd3_4f57_ccb9_04ec);
    }

    /// Sibling members must get distinct streams (the whole point).
    #[test]
    fn member_seed_distinct_within_group() {
        let base = 0xABCD_EF01_2345_6789u64;
        let seeds: Vec<u64> = (0..64).map(|m| member_seed(base, m)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }

    /// Checkpoint contract: a stream restored from `snapshot()` continues
    /// bit-identically — draw-for-draw, across every output flavor — with
    /// the stream it was captured from, and snapshotting is itself
    /// side-effect-free (capturing does not perturb the source stream).
    #[test]
    fn snapshot_restore_roundtrip_bit_identical() {
        let mut src = Pcg64::new(0x5EED_CAFE);
        for _ in 0..37 {
            src.next_u64(); // advance to a mid-stream position
        }
        let (state, inc) = src.snapshot();
        let mut restored = Pcg64::restore(state, inc);
        for i in 0..256 {
            assert_eq!(src.next_u64(), restored.next_u64(), "u64 draw {i}");
        }
        // mixed-type draws must line up too (normal() consumes a variable
        // number of underlying u64s — restore must not skew the cursor)
        for i in 0..64 {
            assert_eq!(src.normal().to_bits(), restored.normal().to_bits(),
                       "normal draw {i}");
            assert_eq!(src.below(977), restored.below(977), "below draw {i}");
        }
        // snapshot of the now-advanced pair still agrees
        assert_eq!(src.snapshot(), restored.snapshot());
    }

    /// Snapshotting must be pure: interleaving snapshots does not change
    /// the stream relative to an unsnapshotted twin.
    #[test]
    fn snapshot_does_not_perturb_stream() {
        let mut a = Pcg64::new(99);
        let mut b = Pcg64::new(99);
        for _ in 0..128 {
            let _ = a.snapshot();
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let frac = counts[2] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "{counts:?}");
    }
}
