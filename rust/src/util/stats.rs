//! Small statistics helpers shared by metrics, benches and the perf model.

/// Running mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (what GRPO's group normalization uses).
pub fn std_pop(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Exponential moving average over a series (used for curve smoothing in
/// bench reports, like the paper's reward plots).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        assert!((r.min - 1.0).abs() < 1e-12);
        assert!((r.max - 10.0).abs() < 1e-12);
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn std_pop_known() {
        let xs = [2.0, 4.0];
        assert!((std_pop(&xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ema_first_is_identity() {
        let out = ema(&[5.0, 7.0], 0.5);
        assert!((out[0] - 5.0).abs() < 1e-12);
        assert!((out[1] - 6.0).abs() < 1e-12);
    }
}
