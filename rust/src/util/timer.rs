//! Wall-clock timing helpers + the bench harness used by `cargo bench`
//! targets (criterion is unavailable offline; every bench is a
//! `harness = false` binary built on this module).

use std::time::Instant;

/// Scope timer: `let _t = Timer::new("phase");` logs on drop.
pub struct Timer {
    label: String,
    start: Instant,
    pub silent: bool,
}

impl Timer {
    pub fn new(label: &str) -> Self {
        Timer { label: label.to_string(), start: Instant::now(), silent: false }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if !self.silent {
            eprintln!("[timer] {}: {:.3}s", self.label, self.elapsed_s());
        }
    }
}

/// Measure a closure: returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Micro-bench result for one case.
#[derive(Clone, Debug)]
pub struct BenchStat {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStat {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        format!(
            "{:40} {:10.3} ms/iter   {:12.1} {unit}/s",
            self.name,
            self.mean_s * 1e3,
            per_iter / self.mean_s
        )
    }
}

/// Run `f` repeatedly: a warmup pass, then up to `max_iters` or
/// `budget_s` seconds, whichever hits first.  Each iteration should do a
/// full unit of work (the harness does no sub-sampling like criterion —
/// artifact executions are milliseconds-scale, far above timer noise).
pub fn bench(name: &str, warmup: usize, max_iters: usize, budget_s: f64,
             mut f: impl FnMut()) -> BenchStat {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters && start.elapsed().as_secs_f64() < budget_s
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchStat {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: samples.get(n / 2).copied().unwrap_or(0.0),
        min_s: samples.first().copied().unwrap_or(0.0),
        max_s: samples.last().copied().unwrap_or(0.0),
    }
}

/// Pretty-print a table of rows with a header; used by the table benches to
/// print the same rows as the paper.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_measures() {
        let stat = bench("noop", 1, 16, 0.5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(stat.iters > 0);
        assert!(stat.min_s <= stat.mean_s && stat.mean_s <= stat.max_s + 1e-12);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
