//! Integration tests for crash-safe checkpoint/resume (ROADMAP item 3):
//! the bit-identical deterministic-resume guarantee, end to end on the
//! mock engine.
//!
//! The harness below (`Mini`) is a miniature trainer running the real
//! production loop shape over a real [`RolloutService`]: requant cadence
//! via `push_weights` (with the engine quantized from a recorded source,
//! exactly like `Trainer::refresh_engine`), the rollout seed cursor, one
//! long-lived [`Pcg64`] noise stream, a param update driven by rewards,
//! and a `take_stats` drain at every step boundary.  It checkpoints and
//! resumes through the real `rl::checkpoint` API — `save`,
//! `load_latest`, `check_config`, `ServiceSnapshot`
//! restore + `reissue_weights` — so these tests exercise the same seam
//! `Trainer::run` does, without needing compiled model artifacts.
//!
//! The contract under test, per leg: run 2N steps uninterrupted vs run
//! N steps / checkpoint / fresh process / resume / run N more — every
//! post-resume step's tokens, logprobs are implied (tokens are argmax
//! over them), rewards, parameter bits, RNG draws, and (on inline legs)
//! placement logs are bit-identical.

use std::path::{Path, PathBuf};

use qurl::coordinator::{EngineFactory, GroupSpec, KvConfig, KvLayout,
                        MockEngine, RolloutService, StealPolicy,
                        StripePolicy};
use qurl::rl::checkpoint::{self, CheckpointError, CheckpointState};
use qurl::runtime::ParamStore;
use qurl::util::hash::fnv1a64;
use qurl::util::json::Json;
use qurl::util::rng::Pcg64;

const N_PARAMS: usize = 24;
const MAX_SEQ: usize = 16;
const VOCAB: usize = 8;
const EOS: i32 = 2;

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qurl_ckpt_it_{tag}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Mock analogue of host quantization: a deterministic signature of the
/// source params, pushed into the engines as the `u64` weight handle.
fn quantize(params: &[f32]) -> u64 {
    let bytes: Vec<u8> =
        params.iter().flat_map(|p| p.to_le_bytes()).collect();
    fnv1a64(&bytes)
}

#[derive(Clone, Copy)]
struct Knobs {
    engines: usize,
    slots: usize,
    threaded: bool,
    paged: bool,
    steal: bool,
    least_loaded: bool,
    requant_every: usize,
    groups_per_step: usize,
    /// crash injection: engine 0 errors at this decode tick (0 = off)
    fail_at_tick: usize,
}

const BASE: Knobs = Knobs {
    engines: 2,
    slots: 2,
    threaded: false,
    paged: false,
    steal: false,
    least_loaded: false,
    requant_every: 2,
    groups_per_step: 3,
    fail_at_tick: 0,
};

fn cfg_json(k: &Knobs) -> Json {
    Json::obj(vec![
        ("engines", Json::num(k.engines as f64)),
        ("slots", Json::num(k.slots as f64)),
        ("threaded", Json::Bool(k.threaded)),
        ("paged", Json::Bool(k.paged)),
        ("steal", Json::Bool(k.steal)),
        ("least_loaded", Json::Bool(k.least_loaded)),
        ("requant_every", Json::num(k.requant_every as f64)),
        ("groups_per_step", Json::num(k.groups_per_step as f64)),
        // control knobs: excluded from the fingerprint, may differ freely
        ("ckpt_every", Json::num(0.0)),
        ("resume", Json::Bool(false)),
    ])
}

fn build_service(k: &Knobs) -> RolloutService<MockEngine> {
    let mut svc = if k.threaded {
        let fs: Vec<EngineFactory<MockEngine>> = (0..k.engines)
            .map(|_| {
                let slots = k.slots;
                Box::new(move || {
                    Ok(MockEngine::new(slots, VOCAB, MAX_SEQ, EOS))
                }) as EngineFactory<MockEngine>
            })
            .collect();
        RolloutService::threaded(fs, MAX_SEQ, EOS).unwrap()
    } else {
        let engs: Vec<MockEngine> = (0..k.engines)
            .map(|i| {
                let mut e = MockEngine::new(k.slots, VOCAB, MAX_SEQ, EOS);
                if i == 0 {
                    e.fail_at_tick = k.fail_at_tick;
                }
                e
            })
            .collect();
        RolloutService::new(engs, MAX_SEQ, EOS)
    };
    if k.least_loaded {
        svc.stripe = StripePolicy::LeastLoaded;
    }
    if k.steal {
        svc.steal = StealPolicy::Idle;
    }
    if k.paged {
        svc.set_kv(KvConfig {
            layout: KvLayout::Paged,
            page_size: 4,
            budget_pages: None,
        });
    }
    svc
}

/// Everything one step's determinism is observable through.
#[derive(Clone, Debug, PartialEq)]
struct Row {
    step: usize,
    /// generated tokens per member, submission order
    tokens: Vec<Vec<i32>>,
    /// per-member sampled-token logprob bits, concatenated (float
    /// parity, not just argmax parity)
    logprobs: Vec<u32>,
    /// reward bits per member (u32::MAX sentinel for unscored members)
    rewards: Vec<u32>,
    /// engine attribution per group (scrubbed on threaded+steal legs,
    /// where placement is live timing)
    engines: Vec<usize>,
    /// param bits after this step's update
    params: Vec<u32>,
    /// the noise draw consumed this step (proves RNG stream position)
    noise: u64,
}

fn scrub_attribution(rows: &[Row]) -> Vec<Row> {
    rows.iter()
        .map(|r| Row { engines: Vec::new(), ..r.clone() })
        .collect()
}

struct Mini {
    k: Knobs,
    cfg: Json,
    rng: Pcg64,
    rollout_seed: i32,
    engine_age: usize,
    /// params the engine weights were last quantized from
    engine_src: Option<Vec<f32>>,
    weights: u64,
    ps: ParamStore,
    ref_params: Vec<f32>,
    svc: RolloutService<MockEngine>,
}

impl Mini {
    fn new(k: Knobs) -> Mini {
        let ps = ParamStore {
            params: (0..N_PARAMS)
                .map(|i| i as f32 * 0.25 - 3.0)
                .collect(),
            m: vec![0.0; N_PARAMS],
            v: vec![0.0; N_PARAMS],
            step: 0,
            a_size: 8,
        };
        let ref_params = ps.params.clone();
        Mini {
            cfg: cfg_json(&k),
            rng: Pcg64::new(0x51_524c ^ 0xABCD),
            rollout_seed: 0x2f2f,
            engine_age: usize::MAX,
            engine_src: None,
            weights: 0,
            ps,
            ref_params,
            svc: build_service(&k),
            k,
        }
    }

    /// One training step: maybe requantize, roll out, drain stats,
    /// update params with reward signal + RNG noise.
    fn step(&mut self, step: usize) -> anyhow::Result<Row> {
        // requant cadence (Trainer::refresh_engine shape): quantize from
        // the current params, remember the source, push at a new epoch
        if self.engine_age >= self.k.requant_every {
            self.weights = quantize(&self.ps.params);
            self.engine_src = Some(self.ps.params.clone());
            self.svc.push_weights(self.weights);
            self.engine_age = 0;
        } else {
            self.engine_age += 1;
        }
        // rollout seed cursor (one bump per rollout call)
        let base = (self.rollout_seed as u32 as u64) << 32;
        self.rollout_seed = self.rollout_seed.wrapping_add(1);
        let mut offset = 0u64;
        for gid in 0..self.k.groups_per_step {
            let size = 2 + gid % 2;
            self.svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: vec![3 + ((step + gid) % 5) as i32; 2 + gid % 3],
                group_size: size,
                max_new: if gid % 2 == 0 { 9 } else { 2 },
                temperature: 1.0,
                top_p: 1.0,
                seed: base | offset,
            });
            offset += size as u64;
        }
        let results = self.svc.run(|gid, res| {
            (res.generated.len() % 3) as f32 + (gid % 2) as f32
        })?;
        let _ = self.svc.take_stats()?; // step-boundary drain
        // param update: rewards + one draw off the long-lived stream
        let noise = self.rng.next_u64();
        let reward_sum: f32 = results
            .iter()
            .flat_map(|g| g.members.iter().filter_map(|m| m.reward))
            .sum();
        let total_tokens: usize =
            results.iter().map(|g| g.generated_tokens()).sum();
        for (i, p) in self.ps.params.iter_mut().enumerate() {
            *p += 0.01 * reward_sum
                + 1e-4 * ((noise >> (i % 32)) & 0xff) as f32
                - 0.002 * ((total_tokens + i) % 7) as f32;
        }
        self.ps.step += 1;
        Ok(Row {
            step,
            tokens: results
                .iter()
                .flat_map(|g| {
                    g.members.iter().map(|m| m.result.generated.clone())
                })
                .collect(),
            logprobs: results
                .iter()
                .flat_map(|g| g.members.iter().flat_map(|m| {
                    m.result.logprobs.iter().map(|l| l.to_bits())
                }))
                .collect(),
            rewards: results
                .iter()
                .flat_map(|g| g.members.iter().map(|m| {
                    m.reward.map(|r| r.to_bits()).unwrap_or(u32::MAX)
                }))
                .collect(),
            engines: results.iter().map(|g| g.engine).collect(),
            params: self.ps.params.iter().map(|p| p.to_bits()).collect(),
            noise,
        })
    }

    /// Checkpoint through the real API, exactly as `Trainer` does after
    /// completing step `next_step - 1`.
    fn checkpoint(&self, dir: &Path, next_step: usize, keep: usize)
                  -> anyhow::Result<PathBuf> {
        let st = CheckpointState {
            step: next_step as u64,
            config: self.cfg.clone(),
            rng: self.rng.snapshot(),
            rollout_seed: self.rollout_seed,
            engine_age: self.engine_age as u64,
            sampler: (0, 0, 0),
            schedule: None,
            service: Some(self.svc.snapshot()?),
            ps: &self.ps,
            ref_params: &self.ref_params,
            prev_params: None,
            engine_params: self.engine_src.as_deref(),
        };
        checkpoint::save(dir, &st, keep)
    }

    /// Fresh-process resume: build everything from scratch (as after a
    /// crash), load the newest good checkpoint, refuse config drift,
    /// restore trainer state, requantize the engine from the SAVED
    /// source, and re-stamp the rebuilt service — the
    /// `Trainer::resume_from_checkpoint` protocol.  Returns the next
    /// step to execute.
    fn resume(k: Knobs, dir: &Path) -> anyhow::Result<(Mini, usize)> {
        let mut mini = Mini::new(k);
        let loaded = checkpoint::load_latest(dir)?;
        checkpoint::check_config(&loaded.manifest.config, &mini.cfg)?;
        mini.rng = loaded.rng();
        mini.rollout_seed = loaded.manifest.rollout_seed;
        mini.engine_age = loaded.manifest.engine_age as usize;
        mini.ps = loaded.ps;
        mini.ref_params = loaded.ref_params;
        if let Some(src) = &loaded.engine_params {
            // requantizing the saved source is bit-identical to the
            // delta-built engine the original run was serving
            mini.weights = quantize(src);
            mini.engine_src = Some(src.clone());
        }
        if let Some(snap) = &loaded.manifest.service {
            mini.svc.restore(snap)?;
            mini.svc.reissue_weights(mini.weights);
        }
        Ok((mini, loaded.manifest.step as usize))
    }
}

fn run_steps(mini: &mut Mini, from: usize, to: usize) -> Vec<Row> {
    (from..to).map(|s| mini.step(s).unwrap()).collect()
}

/// Baseline leg: inline backend, round-robin placement, dense KV.
/// Run 6 steps straight vs 3 + checkpoint + fresh-process resume + 3:
/// every post-resume row (tokens, rewards, attribution, param bits, RNG
/// draws) and the full placement log are bit-identical.
#[test]
fn resume_is_bit_identical_inline_round_robin() {
    let dir = tdir("parity_rr");
    let mut a = Mini::new(BASE);
    let rows_a = run_steps(&mut a, 0, 6);
    // the harness actually produces signal on every fingerprint axis
    assert_eq!(rows_a[0].step, 0);
    assert!(!rows_a[0].tokens.is_empty());
    assert!(!rows_a[0].logprobs.is_empty());
    assert!(!rows_a[0].rewards.is_empty());
    assert!(!rows_a[0].engines.is_empty());
    assert!(!rows_a[0].params.is_empty());
    assert_ne!(rows_a[0].noise, 0);
    let mut b = Mini::new(BASE);
    let _ = run_steps(&mut b, 0, 3);
    b.checkpoint(&dir, 3, 0).unwrap();
    drop(b); // the process goes away
    let (mut c, start) = Mini::resume(BASE, &dir).unwrap();
    assert_eq!(start, 3);
    let rows_c = run_steps(&mut c, start, 6);
    assert_eq!(rows_a[3..], rows_c[..], "post-resume rows diverged");
    assert_eq!(a.svc.placement_log(), c.svc.placement_log(),
               "placement logs diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// Hard-mode inline leg: least-loaded placement + paged KV + work
/// stealing, with the checkpoint taken MID requant interval — the
/// resumed engine must be rebuilt from the saved quantization source
/// (the current params have moved on), and least-loaded placement must
/// continue from the restored load estimates.  Bit-identical including
/// engine attribution and the placement log.
#[test]
fn resume_parity_least_loaded_paged_steal_mid_requant() {
    let k = Knobs {
        least_loaded: true,
        paged: true,
        steal: true,
        requant_every: 3,
        ..BASE
    };
    let dir = tdir("parity_ll_paged_steal");
    let mut a = Mini::new(k);
    let rows_a = run_steps(&mut a, 0, 8);
    let mut b = Mini::new(k);
    let _ = run_steps(&mut b, 0, 2);
    // mid-interval: the engine is serving weights quantized from OLDER
    // params than the current ones
    assert_ne!(b.engine_src.as_deref().unwrap(), &b.ps.params[..],
               "requant cadence not actually mid-interval");
    b.checkpoint(&dir, 2, 0).unwrap();
    drop(b);
    let (mut c, start) = Mini::resume(k, &dir).unwrap();
    assert_eq!(start, 2);
    let rows_c = run_steps(&mut c, start, 8);
    assert_eq!(rows_a[2..], rows_c[..], "post-resume rows diverged");
    assert_eq!(a.svc.placement_log(), c.svc.placement_log(),
               "placement logs diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// The threaded backend with paged KV + stealing: placement under live
/// stealing is thread timing, so engine attribution is scrubbed; the
/// outputs themselves — tokens, rewards, param bits, RNG draws — must
/// still be bit-identical across checkpoint/resume (the service
/// isolation contract makes outputs placement-independent).
#[test]
fn resume_parity_threaded_paged_steal_outputs() {
    let k = Knobs {
        threaded: true,
        paged: true,
        steal: true,
        least_loaded: true,
        engines: 3,
        ..BASE
    };
    let dir = tdir("parity_threaded");
    let mut a = Mini::new(k);
    let rows_a = run_steps(&mut a, 0, 6);
    let mut b = Mini::new(k);
    let _ = run_steps(&mut b, 0, 3);
    b.checkpoint(&dir, 3, 0).unwrap();
    drop(b);
    let (mut c, start) = Mini::resume(k, &dir).unwrap();
    assert_eq!(start, 3);
    let rows_c = run_steps(&mut c, start, 6);
    assert_eq!(scrub_attribution(&rows_a[3..]),
               scrub_attribution(&rows_c),
               "post-resume outputs diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-mid-step recovery: engine 0 is armed to error at a decode tick
/// that lands inside a later step.  The run checkpoints every step,
/// dies mid-step, and a fresh process resumes from the last completed
/// checkpoint — the re-executed remainder is bit-identical to a run
/// that never crashed.
#[test]
fn crash_mid_step_resumes_bit_identically() {
    let steps = 6usize;
    let dir = tdir("crash");
    let mut a = Mini::new(BASE);
    let rows_a = run_steps(&mut a, 0, steps);
    let k = Knobs { fail_at_tick: 25, ..BASE };
    let mut b = Mini::new(k);
    let mut s_fail = None;
    for s in 0..steps {
        match b.step(s) {
            Ok(_) => {
                b.checkpoint(&dir, s + 1, 0).unwrap();
            }
            Err(e) => {
                assert!(format!("{e:#}").contains("injected crash"),
                        "unexpected error: {e:#}");
                s_fail = Some(s);
                break;
            }
        }
    }
    let s_fail = s_fail.expect("fail_at_tick=25 never fired");
    assert!((1..steps).contains(&s_fail),
            "crash tick landed outside the run (step {s_fail})");
    drop(b); // mid-step state dies with the process
    // resume must NOT see the armed tick again (a real restart wouldn't)
    let (mut c, start) = Mini::resume(BASE, &dir).unwrap();
    assert_eq!(start, s_fail, "resumed from the wrong checkpoint");
    let rows_c = run_steps(&mut c, start, steps);
    assert_eq!(rows_a[s_fail..], rows_c[..],
               "post-crash remainder diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// Failure path: the newest checkpoint is corrupted on disk after the
/// crash.  Resume falls back to the previous good one (re-executing one
/// more step) and the rerun is still bit-identical.
#[test]
fn corrupted_newest_falls_back_and_stays_bit_identical() {
    let dir = tdir("fallback_it");
    let mut a = Mini::new(BASE);
    let rows_a = run_steps(&mut a, 0, 6);
    let mut b = Mini::new(BASE);
    for s in 0..4 {
        b.step(s).unwrap();
        b.checkpoint(&dir, s + 1, 0).unwrap();
    }
    drop(b);
    let victim = dir.join("step_000004").join("params.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&victim, &bytes).unwrap();
    let (mut c, start) = Mini::resume(BASE, &dir).unwrap();
    assert_eq!(start, 3, "did not fall back past the corrupted snapshot");
    let rows_c = run_steps(&mut c, start, 6);
    assert_eq!(rows_a[3..], rows_c[..], "fallback rerun diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// Failure path: resuming under a silently-changed config is a typed
/// refusal naming the differing field — never a quietly-different run.
#[test]
fn changed_config_is_refused_with_the_field_named() {
    let dir = tdir("cfg_refusal");
    let mut b = Mini::new(BASE);
    b.step(0).unwrap();
    b.checkpoint(&dir, 1, 0).unwrap();
    drop(b);
    let changed = Knobs { requant_every: 5, ..BASE };
    let err = Mini::resume(changed, &dir).unwrap_err();
    match err.downcast_ref::<CheckpointError>() {
        Some(CheckpointError::ConfigMismatch { field, .. }) => {
            assert_eq!(field, "requant_every");
        }
        other => panic!("wrong error: {other:?}"),
    }
    // the original config still resumes fine
    assert!(Mini::resume(BASE, &dir).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Retention through the training loop: `keep = 2` with a checkpoint
/// every step leaves exactly the newest two snapshots, and the survivor
/// set still resumes bit-identically.
#[test]
fn retention_keeps_newest_k_through_the_loop() {
    let dir = tdir("retention_it");
    let mut a = Mini::new(BASE);
    let rows_a = run_steps(&mut a, 0, 6);
    let mut b = Mini::new(BASE);
    for s in 0..5 {
        b.step(s).unwrap();
        b.checkpoint(&dir, s + 1, 2).unwrap();
    }
    drop(b);
    for gone in 1..=3u64 {
        assert!(!dir.join(checkpoint::step_dir_name(gone)).exists(),
                "gc left step {gone}");
    }
    for kept in 4..=5u64 {
        assert!(dir.join(checkpoint::step_dir_name(kept)).exists(),
                "gc deleted step {kept}");
    }
    let (mut c, start) = Mini::resume(BASE, &dir).unwrap();
    assert_eq!(start, 5);
    let rows_c = run_steps(&mut c, start, 6);
    assert_eq!(rows_a[5..], rows_c[..], "post-gc resume diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// CI artifact: time one save/load cycle on the mock-trainer state and
/// emit `results/BENCH_ckpt.json` (+ a manifest copy) for the workflow
/// to upload.  This is a smoke emission, not a perf assertion.
#[test]
fn bench_ckpt_smoke_emits_artifact() {
    let dir = tdir("bench");
    let mut m = Mini::new(BASE);
    let _ = run_steps(&mut m, 0, 2);
    let t0 = std::time::Instant::now();
    let path = m.checkpoint(&dir, 2, 0).unwrap();
    let save_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let loaded = checkpoint::load_latest(&dir).unwrap();
    let load_s = t1.elapsed().as_secs_f64();
    assert_eq!(loaded.manifest.step, 2);
    let bytes: u64 = std::fs::read_dir(&path)
        .unwrap()
        .flatten()
        .filter_map(|e| e.metadata().ok())
        .map(|md| md.len())
        .sum();
    let report = Json::obj(vec![
        ("save_s", Json::num(save_s)),
        ("load_s", Json::num(load_s)),
        ("bytes", Json::num(bytes as f64)),
        ("payloads", Json::num(loaded.manifest.payloads.len() as f64)),
        ("n_params", Json::num(N_PARAMS as f64)),
    ]);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_ckpt.json", report.to_string()).ok();
    std::fs::copy(path.join("manifest.json"),
                  "results/ckpt_manifest.json")
        .ok();
    std::fs::remove_dir_all(&dir).ok();
}
