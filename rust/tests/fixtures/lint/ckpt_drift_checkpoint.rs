// Lint fixture: a miniature rl/checkpoint.rs for the config_drift
// checkpoint-manifest axis.  One violation is seeded: `rng_inc` is
// written by `to_json` but never read back in `from_json`, so a resumed
// run would silently lose the RNG stream selector.  `step` and
// `rng_state` round-trip and must stay quiet.

pub struct CheckpointManifest {
    pub step: u64,
    pub rng_state: u128,
    pub rng_inc: u128,
}

impl CheckpointManifest {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"{}\":{},\"{}\":{},\"{}\":{}}}",
            "step", self.step, "rng_state", self.rng_state, "rng_inc",
            self.rng_inc,
        )
    }

    pub fn from_json(raw: &str) -> CheckpointManifest {
        let step = field(raw, "step");
        let rng_state = field(raw, "rng_state");
        CheckpointManifest { step: step as u64, rng_state, rng_inc: 0 }
    }
}

fn field(_raw: &str, _key: &str) -> u128 {
    0
}
