//! Config-drift fixture (config/mod.rs role).  `kv_layout` is written
//! by `to_json` but silently reset to a default in `from_json` — the
//! classic round-trip drift where a saved run reloads with a different
//! KV layout than it ran with.

pub fn to_json(c: &TrainerConfig) -> String {
    let mut s = String::new();
    s.push_str(&kv("steps", c.steps));
    s.push_str(&kv("kv_layout", &c.kv_layout));
    s.push_str(&kv("seed", c.seed));
    s.push_str(&kv("temp", c.temp));
    s
}

pub fn from_json(j: &Json) -> TrainerConfig {
    TrainerConfig {
        steps: j.get("steps"),
        seed: j.get("seed"),
        temp: j.get("temp"),
        // seeded violation: no "kv_layout" key read back
        kv_layout: default_kv(),
    }
}
