//! Config-drift fixture (main.rs role).  Registers `--steps`, the
//! `--kv` alias for `kv_layout`, and — seeded violation — `--temp`,
//! which the pass's CONFIG_ONLY list says must stay preset-only.
//! `seed` gets no flag at all.

fn train_cli() -> Cli {
    Cli::new("train")
        .opt("steps", "200", "training steps")
        .opt("kv", "dense", "kv layout: dense|paged")
        .opt("temp", "1.0", "sampling temperature")
}
