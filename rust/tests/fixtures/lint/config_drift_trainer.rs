//! Config-drift fixture (trainer.rs role): four fields covering every
//! outcome — fully wired, missing from `from_json`, missing a CLI
//! flag, and a stale `CONFIG_ONLY` entry.

pub struct TrainerConfig {
    pub steps: usize,
    pub kv_layout: String,
    pub seed: u64,
    pub temp: f32,
}
