//! Panic-wall fixture: two seeded violations (`unwrap`,
//! `unreachable!`), one malformed annotation, and every quiet case —
//! an annotated `expect`, a `#[cfg(test)]` unwrap, and panic-looking
//! text in comments, strings, and raw strings.

pub fn hot(q: &mut Queue) -> Step {
    // a comment mentioning panic!("boom") and .unwrap() must stay quiet
    let msg = "this string says x.unwrap() and panic!";
    let raw = r#"raw "panic!" text with .expect( too"#;
    log(msg, raw);
    let slot = q.free.pop().unwrap();
    match q.kind {
        Kind::A => step_a(slot),
        _ => unreachable!(),
    }
}

pub fn annotated(q: &Queue) -> u64 {
    // lint: allow(panic, queue non-empty by the admission invariant)
    q.ids.first().expect("non-empty by admission")
}

// lint: allow(panic, )
pub fn under_malformed_annotation() {}

#[cfg(test)]
mod tests {
    #[test]
    fn test_only_unwraps_are_fine() {
        make().unwrap();
    }
}
