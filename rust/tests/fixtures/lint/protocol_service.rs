//! Protocol fixture (service.rs role): `Command::Dead` is only ever
//! matched (dead variant), `Command::Unhandled` only ever constructed
//! (the service loop would wedge on it).  `Submit` and `Finished`
//! exercise both classifications, including `if let` matching.

pub enum Command {
    Submit(u64),
    Dead,
    Unhandled,
}

pub enum Event {
    Finished(u64),
}

pub fn run(rx: &Receiver) {
    send(Command::Submit(1));
    send(Command::Unhandled);
    loop {
        match rx.recv() {
            Command::Submit(id) => handle(id),
            Command::Dead => return,
            _ => drop_it(),
        }
    }
}

pub fn emit() -> Event {
    Event::Finished(3)
}

pub fn pump(ev: Event) {
    if let Event::Finished(id) = ev {
        done(id);
    }
}
