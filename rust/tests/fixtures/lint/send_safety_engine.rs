//! Send-safety fixture (engine.rs role): the blessed construction
//! site — `StepEngine::new` inside the closure `StepEngine::factory`
//! returns, realized on the worker thread.

pub struct StepEngine;

impl StepEngine {
    pub fn factory(dir: PathBuf, weights: Weights) -> EngineFactory {
        Box::new(move || {
            let rt = Arc::new(Runtime::open(&dir)?);
            Ok(StepEngine::new(&rt, weights))
        })
    }

    pub fn new(rt: &Arc<Runtime>, weights: Weights) -> StepEngine {
        build(rt, weights)
    }
}
