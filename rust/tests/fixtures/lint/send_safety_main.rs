//! Send-safety fixture (main.rs role): seeded violation — an engine
//! constructed outside `StepEngine::factory`, with no allow(send)
//! annotation, so PJRT state could cross a thread boundary.

pub fn cmd_serve(rt: &Arc<Runtime>, weights: Weights) {
    let engines: Vec<StepEngine> = (0..2)
        .map(|_| StepEngine::new(rt, weights.clone()))
        .collect();
    drive(engines);
}
