//! Stats-catalog fixture (recorder.rs role): the `sched_*` field
//! catalog lives in module doc comments, exactly like the real
//! metrics/recorder.rs.  The catalog below deliberately omits the
//! decode-steps key so the catalog axis of the pass fires.
//!
//! | key              | meaning                              |
//! |------------------|--------------------------------------|
//! | `sched_submitted`| requests admitted to the queue       |
//! | `sched_completed`| requests finished this step          |
//! | `sched_occupancy`| mean busy slots per decode tick      |

pub struct Recorder;
