//! Stats-catalog fixture (request.rs role): `submitted` is seeded as
//! missing from `merge` — the drift axis the pass must catch.

pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub decode_steps: u64,
    pub occupancy_sum: f64,
}

impl SchedulerStats {
    pub fn merge(&mut self, o: &SchedulerStats) {
        // seeded violation: `self.submitted` deliberately not accumulated
        self.completed += o.completed;
        self.decode_steps += o.decode_steps;
        self.occupancy_sum += o.occupancy_sum;
    }
}
