//! Stats-catalog fixture (trainer.rs role): the recorder row writes.
//!
//! Regression note: on day one this pass found the real repo's
//! submitted / completed / decode-steps counters missing from the live
//! trainer row and the recorder catalog (fixed in the same PR).  This
//! fixture seeds that exact gap for the decode-steps key — the comment
//! spells it out in prose only, because the emit check reads string
//! literals, and the catalog check must not see the key here either.

pub fn emit(r: &mut Row, st: &SchedulerStats, ticks: f64) {
    r.set("sched_submitted", st.submitted as f64);
    r.set("sched_completed", st.completed as f64);
    r.set("sched_occupancy", st.occupancy_sum / ticks);
}
