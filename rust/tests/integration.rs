//! Integration tests over the real artifacts (require `make artifacts`).
//!
//! Each test opens its own Runtime; tests are grouped to amortize artifact
//! compilation.  Run via `make test` (pytest covers the Python side).

use std::path::Path;
use std::sync::Arc;

use qurl::coordinator::{DecodeEngine, GroupSpec, KvConfig, KvLayout,
                        PrunePolicy, RolloutRequest, RolloutService,
                        Scheduler, StepEngine, StripePolicy};
use qurl::metrics::Recorder;
use qurl::quant::{analysis, fp8 as qfp8, int8 as qint8};
use qurl::rl::{Objective, ObjectiveKind, RolloutExec, RolloutPath, Trainer,
               TrainerConfig};
use qurl::runtime::{EngineWeights, ParamStore, QuantMode, Runtime,
                    TrainBatch};
use qurl::tasks::{encode_batch, Problem, Suite, Tokenizer};

fn runtime() -> Arc<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Arc::new(Runtime::open(&dir).expect("run `make artifacts` before cargo \
                                         test"))
}

fn test_prompts(rt: &Runtime, n: usize) -> (Vec<i32>, Vec<i32>, Vec<usize>) {
    let man = rt.manifest();
    let (b, s) = (man.rollout_batch, man.max_seq);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let probs = suite.test_set(42, n.div_ceil(6) + 1);
    let refs: Vec<&qurl::tasks::Problem> =
        probs.iter().take(n).map(|(_, p)| p).collect();
    let (tokens, lens) = encode_batch(&tk, &refs, b, s, man.max_prompt);
    let plens = refs.iter().map(|p| tk.encode_prompt(&p.prompt).len()).collect();
    (tokens, lens, plens)
}

/// Bulk-generate behavior logprobs must equal teacher-forced logprobs under
/// the SAME engine weights — the premise of decoupled-PPO importance
/// sampling (pi_behav is exactly what the engine reports).
#[test]
fn generate_logprobs_match_engine_scoring() {
    let rt = runtime();
    let params = rt.init_params(3).unwrap();
    let man = rt.manifest().clone();
    let (tokens, lens, _) = test_prompts(&rt, 12);
    for mode in [QuantMode::Int8, QuantMode::Bf16] {
        let w = rt.engine_weights(mode, &params).unwrap();
        let gen = rt.generate(&w, &tokens, &lens, 7, 1.0, 1.0).unwrap();
        let lp_engine = rt.score_engine(&w, &gen.tokens).unwrap();
        let mut max_diff = 0.0f32;
        let mut mean_diff = 0.0f64;
        let mut n = 0.0f64;
        for i in 0..gen.mask.len() {
            if gen.mask[i] > 0.5 {
                let d = (gen.logprob[i] - lp_engine[i]).abs();
                max_diff = max_diff.max(d);
                mean_diff += d as f64;
                n += 1.0;
            }
        }
        // bf16: pure reassociation noise.  int8/fp8: a 1-ulp activation
        // difference between the KV-decode and teacher-forced shapes can
        // flip a quantization rounding — the same decode-vs-rescore
        // "engine discrepancy" FlashRL reports for vLLM-vs-HF, appearing
        // here organically.  Mean must stay tiny; max bounded.
        let tol = if mode == QuantMode::Bf16 { 2e-4 } else { 5e-2 };
        assert!(max_diff < tol, "{mode:?}: lp mismatch {max_diff}");
        assert!(mean_diff / n < 2e-3, "{mode:?}: mean lp gap {}",
                mean_diff / n);
        // and the quantized engine must differ from the fp actor (that gap
        // is the whole point of the paper)
        if mode == QuantMode::Int8 {
            let lp_fp = rt.score_bf16(&params, &gen.tokens).unwrap().logprob;
            let mut mean_gap = 0.0;
            let mut n = 0.0;
            for i in 0..gen.mask.len() {
                if gen.mask[i] > 0.5 {
                    mean_gap += (lp_fp[i] - gen.logprob[i]).abs() as f64;
                    n += 1.0;
                }
            }
            assert!(mean_gap / n > 1e-5, "quantization gap vanished");
        }
    }
    let _ = man;
}

/// Greedy decode through the step-wise scheduler must match the fused
/// generate artifact token-for-token (padding/batching invariance).
#[test]
fn scheduler_matches_bulk_generate_greedy() {
    let rt = runtime();
    let params = rt.init_params(5).unwrap();
    let man = rt.manifest().clone();
    let w = rt.engine_weights(QuantMode::Int8, &params).unwrap();
    let (tokens, lens, plens) = test_prompts(&rt, 6);
    let gen = rt.generate(&w, &tokens, &lens, 1, 0.0, 1.0).unwrap();

    let mut engine = StepEngine::new(&rt, w.clone());
    let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
    let s = man.max_seq;
    for (r, &plen) in plens.iter().enumerate() {
        sched.submit(RolloutRequest {
            id: r as u64,
            prompt: Arc::new(tokens[r * s..r * s + plen].to_vec()),
            max_new: man.max_new,
            temperature: 0.0,
            top_p: 1.0,
            seed: r as u64,
        });
    }
    let mut results = sched.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 6);
    for res in &results {
        let r = res.id as usize;
        let plen = plens[r];
        let bulk_row = &gen.tokens[r * s..(r + 1) * s];
        let bulk_gen: Vec<i32> = (0..man.max_new)
            .map(|i| bulk_row[plen + i])
            .take_while(|&t| t != man.pad_id)
            .collect();
        let step_gen: Vec<i32> = res.generated.clone();
        // compare up to the shorter (bulk pads after EOS, step stops)
        let n = bulk_gen.len().min(step_gen.len());
        assert!(n > 0, "request {r} generated nothing");
        assert_eq!(&bulk_gen[..n], &step_gen[..n],
                   "greedy divergence on request {r}");
    }
}

/// Tentpole parity: with temp=0 the trainer's scheduler rollout path —
/// the group-aware RolloutService, including fork_kv shared-prefix
/// prefill, multi-engine placement (rr AND least-loaded) and the THREADED
/// executor (one worker thread per StepEngine replica, each opening its
/// own Runtime) — must reproduce the fused path's completions, masks and
/// rewards bit-for-bit, so `--rollout-path scheduler --rollout-exec
/// threaded` changes serving wall-clock, not learning.
#[test]
fn trainer_scheduler_path_matches_fused_greedy() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let params = rt.init_params(21).unwrap();
    let suite = Suite::by_name("deepscaler").unwrap();
    let mut sampler = suite.train_sampler(99);
    let probs: Vec<Problem> = (0..3).map(|_| sampler.next().1).collect();
    let g = 2usize;
    let expanded: Vec<(usize, &Problem)> = probs
        .iter()
        .enumerate()
        .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
        .collect();
    let rollout_with = |path: RolloutPath, engines: usize,
                        exec: RolloutExec, stripe: StripePolicy|
                       -> Vec<qurl::rl::Sample> {
        let cfg = TrainerConfig {
            temp: 0.0,
            top_p: 1.0,
            rollout_mode: QuantMode::Int8,
            rollout_path: path,
            group_size: g,
            rollout_engines: engines,
            rollout_exec: exec,
            rollout_stripe: stripe,
            ..TrainerConfig::default()
        };
        let base = ParamStore::new(&man, params.clone());
        let mut t = Trainer::new(&rt, cfg, base,
                                 Recorder::ephemeral("parity")).unwrap();
        t.prepare().unwrap();
        t.rollout(&expanded).unwrap()
    };
    let fused = rollout_with(RolloutPath::Fused, 1, RolloutExec::Inline,
                             StripePolicy::RoundRobin);
    let sched = rollout_with(RolloutPath::Scheduler, 1, RolloutExec::Inline,
                             StripePolicy::RoundRobin);
    // striping across 2 replicas, least-loaded placement, and threaded
    // workers must not change any sample either
    let variants = [
        rollout_with(RolloutPath::Scheduler, 2, RolloutExec::Inline,
                     StripePolicy::RoundRobin),
        rollout_with(RolloutPath::Scheduler, 2, RolloutExec::Inline,
                     StripePolicy::LeastLoaded),
        rollout_with(RolloutPath::Scheduler, 2, RolloutExec::Threaded,
                     StripePolicy::LeastLoaded),
    ];
    assert_eq!(fused.len(), sched.len());
    for (i, (a, b)) in fused.iter().zip(&sched).enumerate() {
        assert_eq!(a.tokens, b.tokens, "greedy token divergence on {i}");
        assert_eq!(a.mask, b.mask, "mask divergence on {i}");
        assert_eq!(a.prompt_len, b.prompt_len);
        assert_eq!(a.reward, b.reward, "reward divergence on {i}");
        assert_eq!(a.group, b.group);
    }
    for (v, variant) in variants.iter().enumerate() {
        assert_eq!(variant.len(), sched.len());
        for (i, (a, b)) in sched.iter().zip(variant).enumerate() {
            assert_eq!(a.tokens, b.tokens,
                       "variant {v} token divergence on {i}");
            assert_eq!(a.reward, b.reward,
                       "variant {v} reward divergence on {i}");
            assert_eq!(a.group, b.group);
        }
    }
}

/// Hot requantization through the trainer: with `requantize_every = 1` on
/// the scheduler path, every step re-quantizes — and the rollout service
/// must survive all of them (built exactly once, weights hot-swapped via
/// WeightEpoch; the old path set `service = None` per step and rebuilt N
/// engines).  The per-step `sched_weight_epoch` metric must track the
/// swap count.
#[test]
fn requantize_hot_swaps_instead_of_rebuilding_service() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let params = rt.init_params(43).unwrap();
    let cfg = TrainerConfig {
        rollout_mode: QuantMode::Int8,
        rollout_path: RolloutPath::Scheduler,
        rollout_engines: 2,
        requantize_every: 1,
        steps: 3,
        prompts_per_step: 2,
        group_size: 2,
        eval_every: 0,
        ..TrainerConfig::default()
    };
    let base = ParamStore::new(&man, params);
    let mut t = Trainer::new(&rt, cfg, base,
                             Recorder::ephemeral("hotswap")).unwrap();
    for step in 0..3 {
        t.step(step).unwrap();
    }
    assert_eq!(t.service_builds(), 1,
               "requantize path rebuilt the rollout service");
    // step 0 serves epoch 0 (build weights), each later step swaps once
    let epochs: Vec<f64> = t
        .rec
        .series("sched_weight_epoch")
        .iter()
        .map(|&(_, v)| v)
        .collect();
    assert_eq!(epochs.len(), 3);
    assert_eq!(epochs, vec![0.0, 1.0, 2.0],
               "weight epoch did not advance with requantization");
}

fn greedy_tok(v: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i32
}

/// fork_kv contract on the real artifacts: a slot whose KV rows were
/// forked from a prefilled sibling must decode bit-for-bit identically to
/// both the source slot and an independently prefilled slot, for the whole
/// greedy trajectory.  Runs the default prefix-limited fork (only
/// `prompt_len` positions copied per head) AND the full-`max_seq`-row
/// debug path — establishing the masking guarantee that positions beyond
/// the prompt are never read before the sequence's own decode writes
/// them, which is what makes the ~`max_seq/prompt_len`× cheaper prefix
/// copy exact.
#[test]
fn fork_kv_matches_fresh_prefill_artifacts() {
    let rt = runtime();
    let man = rt.manifest().clone();
    assert!(man.rollout_batch >= 3);
    let params = rt.init_params(31).unwrap();
    let w = rt.engine_weights(QuantMode::Int8, &params).unwrap();
    let (tokens, _, plens) = test_prompts(&rt, 1);
    let prompt = tokens[..plens[0]].to_vec();
    assert!(prompt.len() < man.max_seq,
            "prefix fork degenerates to full-row on this manifest");
    let run = |full_row: bool| -> Vec<Vec<f32>> {
        let mut eng = StepEngine::new(&rt, w.clone());
        eng.full_row_fork = full_row;
        // slots 0 and 2 prefill independently; slot 1 forks from slot 0
        let logits = eng
            .prefill(&[0, 2], &[prompt.as_slice(), prompt.as_slice()])
            .unwrap();
        assert_eq!(logits[0].as_slice(), logits[1].as_slice(),
                   "same prompt, same prefill logits");
        eng.fork_kv(0, &[1], prompt.len()).unwrap();
        let mut trajectory: Vec<Vec<f32>> = Vec::new();
        let mut pos = prompt.len() - 1;
        let mut tok = greedy_tok(logits[0].as_slice());
        for _ in 0..16 {
            pos += 1;
            if pos + 1 >= man.max_seq || tok == man.eos_id {
                break;
            }
            let p = pos as i32;
            let lg = eng
                .decode(&[(0, p, tok), (1, p, tok), (2, p, tok)])
                .unwrap();
            assert_eq!(lg[0].as_slice(), lg[1].as_slice(),
                       "forked slot diverged from source @ {pos} \
                        (full_row={full_row})");
            assert_eq!(lg[0].as_slice(), lg[2].as_slice(),
                       "forked slot diverged from fresh prefill @ {pos} \
                        (full_row={full_row})");
            trajectory.push(lg[1].as_slice().to_vec());
            tok = greedy_tok(lg[0].as_slice());
        }
        assert!(!trajectory.is_empty());
        trajectory
    };
    // the prefix-limited copy must be bit-identical to the full-row copy
    // along the forked slot's whole trajectory — the masking guarantee
    assert_eq!(run(false), run(true),
               "prefix-limited fork diverged from full-row fork");
}

/// The resident-input contract on the real artifacts: cached weight
/// literals + recycled KV literals must produce bit-for-bit the same
/// scheduler outputs as the per-call conversion path — across a mid-run
/// `swap_weights` (a stale weight cache would keep decoding under the old
/// epoch and fail this), for greedy AND sampled requests.
#[test]
fn resident_inputs_match_per_call_across_weight_swap() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let w0 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(51).unwrap())
        .unwrap();
    let w1 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(52).unwrap())
        .unwrap();
    let (tokens, _, plens) = test_prompts(&rt, 4);
    let s = man.max_seq;
    let run = |resident: bool| {
        let mut eng = StepEngine::new(&rt, w0.clone());
        eng.set_resident(resident);
        assert_eq!(eng.is_resident(), resident);
        let mut sched = Scheduler::new(&mut eng, man.max_seq, man.eos_id);
        for (r, &plen) in plens.iter().enumerate() {
            sched.submit(RolloutRequest {
                id: r as u64,
                prompt: Arc::new(tokens[r * s..r * s + plen].to_vec()),
                max_new: man.max_new.min(12),
                // mix greedy and sampled
                temperature: if r % 2 == 0 { 0.0 } else { 1.0 },
                top_p: 0.9,
                seed: 77 ^ r as u64,
            });
        }
        // a few ticks under w0, then hot-swap to w1 mid-flight
        for _ in 0..3 {
            sched.tick().unwrap();
        }
        sched.swap_weights(w1.clone(), 1);
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), plens.len());
        results
            .into_iter()
            .map(|r| (r.id, r.generated,
                      r.logprobs.iter().map(|l| l.to_bits()).collect::<Vec<_>>()))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(true), run(false),
               "resident-input path diverged from per-call literals");
}

/// Paged KV on the real artifacts: the page table is pure logical
/// bookkeeping over the dense physical cache, so `--kv paged` with
/// chunked prefill must reproduce the dense scheduler outputs
/// bit-for-bit — across a mid-run `swap_weights`, for greedy AND sampled
/// requests — while the page ledger drains leak-free.  Budget is
/// unbounded and the chunk setting identical in both runs so admission
/// timing (hence where the swap lands) cannot differ.
#[test]
fn paged_kv_matches_dense_across_weight_swap_artifacts() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let w0 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(53).unwrap())
        .unwrap();
    let w1 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(54).unwrap())
        .unwrap();
    let (tokens, _, plens) = test_prompts(&rt, 4);
    let s = man.max_seq;
    let run = |layout: KvLayout| {
        let mut eng = StepEngine::new(&rt, w0.clone());
        let out;
        {
            let mut sched = Scheduler::new(&mut eng, man.max_seq,
                                           man.eos_id);
            sched.set_kv(KvConfig {
                layout,
                page_size: 8,
                budget_pages: None,
            });
            sched.prefill_chunk = 4; // same in both runs: same timing
            for (r, &plen) in plens.iter().enumerate() {
                sched.submit(RolloutRequest {
                    id: r as u64,
                    prompt: Arc::new(tokens[r * s..r * s + plen].to_vec()),
                    max_new: man.max_new.min(12),
                    temperature: if r % 2 == 0 { 0.0 } else { 1.0 },
                    top_p: 0.9,
                    seed: 91 ^ r as u64,
                });
            }
            for _ in 0..3 {
                sched.tick().unwrap();
            }
            sched.swap_weights(w1.clone(), 1);
            let mut results = sched.run_to_completion().unwrap();
            results.sort_by_key(|r| r.id);
            assert_eq!(results.len(), plens.len());
            let st = sched.take_stats();
            assert_eq!(st.kv_pages_freed, st.kv_pages_allocated,
                       "{layout:?}: page ledger leaked");
            assert_eq!(st.kv_pages_active, 0);
            if layout == KvLayout::Paged {
                assert!(st.prefill_chunks > 0,
                        "prefill_chunk=4 never chunked");
                assert!(st.kv_pages_allocated > 0);
            }
            out = results
                .into_iter()
                .map(|r| (r.id, r.generated,
                          r.logprobs.iter().map(|l| l.to_bits())
                              .collect::<Vec<_>>()))
                .collect::<Vec<_>>();
        }
        assert!(eng.pager().drained(), "{layout:?}: pager not drained");
        assert!(eng.pager().check_invariants());
        out
    };
    assert_eq!(run(KvLayout::Dense), run(KvLayout::Paged),
               "paged KV diverged from the dense oracle");
}

/// The acceptance criterion on weight traffic: with resident inputs,
/// decode ticks between weight swaps stage ~zero weight bytes (only the
/// per-slot control vectors), while every tick on the per-call path pays
/// the full conversion; a swap re-stages the weights exactly once.
#[test]
fn resident_weights_convert_once_per_epoch() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let w0 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(61).unwrap())
        .unwrap();
    let w1 = rt
        .engine_weights(QuantMode::Int8, &rt.init_params(62).unwrap())
        .unwrap();
    let (tokens, _, plens) = test_prompts(&rt, 1);
    let prompt = tokens[..plens[0]].to_vec();
    let mut eng = StepEngine::new(&rt, w0);
    let wb = eng.weight_bytes();
    assert!(wb > 0);
    let logits = eng.prefill(&[0], &[prompt.as_slice()]).unwrap();
    let mut tok = greedy_tok(logits[0].as_slice());
    let mut pos = (prompt.len() - 1) as i32;
    let step = |eng: &mut StepEngine, tok: &mut i32, pos: &mut i32| {
        *pos += 1;
        assert!((*pos as usize) + 1 < man.max_seq, "test prompt too long");
        let lg = eng.decode(&[(0, *pos, *tok)]).unwrap();
        *tok = greedy_tok(lg[0].as_slice());
    };
    // first decode after prefill re-stages the merged KV once; drain it
    step(&mut eng, &mut tok, &mut pos);
    eng.take_transfer();
    // steady state: N decode ticks must stage neither weights nor KV —
    // h2d collapses to the two [B] control vectors per tick
    let n = 4;
    for _ in 0..n {
        step(&mut eng, &mut tok, &mut pos);
    }
    let (h2d, _) = eng.take_transfer();
    let control = (2 * 4 * man.rollout_batch * n) as u64;
    assert_eq!(h2d, control,
               "steady-state decode staged more than control tensors \
                ({h2d} bytes vs {control}; weights are {wb})");
    // hot swap: the next decode stages the new weights exactly once...
    eng.swap_weights(w1, 1);
    step(&mut eng, &mut tok, &mut pos);
    let (h2d, _) = eng.take_transfer();
    assert!(h2d >= wb, "swap did not restage weights: {h2d} < {wb}");
    // ...and the tick after that is back to control-vector bytes
    step(&mut eng, &mut tok, &mut pos);
    let (h2d, _) = eng.take_transfer();
    assert_eq!(h2d, (2 * 4 * man.rollout_batch) as u64,
               "post-swap decode still staging weights ({h2d} bytes)");
}

fn f32_bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Payload-level bit equality between two weight builds (Arc identity is
/// deliberately NOT required — a delta build shares storage, a full build
/// never does; only the bits must agree).
fn assert_weights_bits_eq(x: &EngineWeights, y: &EngineWeights, ctx: &str) {
    use EngineWeights as W;
    match (x, y) {
        (W::Bf16 { flat: xf }, W::Bf16 { flat: yf }) => {
            assert!(f32_bits(xf) == f32_bits(yf), "{ctx}: bf16 flat differs");
        }
        (W::Int8 { a: xa, qw: xw, qs: xs },
         W::Int8 { a: ya, qw: yw, qs: ys }) => {
            assert!(f32_bits(xa) == f32_bits(ya),
                    "{ctx}: int8 section A differs");
            assert!(xw == yw, "{ctx}: int8 codes differ");
            assert!(f32_bits(xs) == f32_bits(ys), "{ctx}: int8 scales differ");
        }
        (W::Fp8 { a: xa, b_fq: xq }, W::Fp8 { a: ya, b_fq: yq }) => {
            assert!(f32_bits(xa) == f32_bits(ya),
                    "{ctx}: fp8 section A differs");
            assert!(f32_bits(xq) == f32_bits(yq),
                    "{ctx}: fp8 fake-quant differs");
        }
        _ => panic!("{ctx}: quantization mode mismatch"),
    }
}

/// Delta requantization is bit-identical to the full rebuild it replaces —
/// the acceptance criterion of the change-aware refresh.  For every mode:
/// a cold delta (no previous epoch) equals the full build; a refresh under
/// identical params changes nothing and reuses every payload Arc-for-Arc;
/// a refresh after a localized update (section A plus ONE section-B
/// matrix) equals the full build bitwise while the report shows the
/// untouched tensors skipped.  For the quantized modes, a scheduler run
/// that hot-swaps the delta-built weights mid-flight must produce
/// bit-identical rollouts to the same run swapping in the full build.
#[test]
fn delta_requant_matches_full_rebuild_bitwise() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let n_tensors = man.params.len();
    let p0 = rt.init_params(71).unwrap();
    // a localized update: all of section A nudged, one B matrix rescaled
    let mut first_mat: Option<(usize, usize)> = None;
    analysis::for_each_mat(&man, |_, off, k, n| {
        if first_mat.is_none() {
            first_mat = Some((off, k * n));
        }
    });
    let (moff, mlen) = first_mat.unwrap();
    let mut p1 = p0.clone();
    for v in &mut p1[..man.a_size] {
        *v += 0.25;
    }
    for v in &mut p1[man.a_size + moff..man.a_size + moff + mlen] {
        *v *= 1.5;
    }
    let (tokens, _, plens) = test_prompts(&rt, 3);
    let s = man.max_seq;
    let rollout = |w_start: &EngineWeights, w_swap: &EngineWeights| {
        let mut eng = StepEngine::new(&rt, w_start.clone());
        let mut sched = Scheduler::new(&mut eng, man.max_seq, man.eos_id);
        for (r, &plen) in plens.iter().enumerate() {
            sched.submit(RolloutRequest {
                id: r as u64,
                prompt: Arc::new(tokens[r * s..r * s + plen].to_vec()),
                max_new: man.max_new.min(10),
                temperature: if r % 2 == 0 { 0.0 } else { 1.0 },
                top_p: 0.9,
                seed: 5 ^ r as u64,
            });
        }
        for _ in 0..2 {
            sched.tick().unwrap();
        }
        sched.swap_weights(w_swap.clone(), 1);
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        results
            .into_iter()
            .map(|r| (r.id, r.generated,
                      r.logprobs.iter().map(|l| l.to_bits())
                          .collect::<Vec<_>>()))
            .collect::<Vec<_>>()
    };
    for mode in [QuantMode::Int8, QuantMode::Fp8, QuantMode::Bf16] {
        // cold start: no previous epoch, delta degenerates to the full path
        let full0 = rt.engine_weights(mode, &p0).unwrap();
        let (d0, r0) = rt.engine_weights_delta(mode, &p0, None).unwrap();
        assert_weights_bits_eq(&d0, &full0, &format!("{mode:?} cold"));
        assert_eq!(r0.tensors_changed, n_tensors, "{mode:?} cold report");
        // identical params requantize identically: nothing changes and
        // every payload is the PREVIOUS epoch's Arc (zero allocation too)
        let (same, rs) = rt.engine_weights_delta(mode, &p0, Some(&d0)).unwrap();
        assert_eq!((rs.tensors_changed, rs.tensors_skipped), (0, n_tensors),
                   "{mode:?} no-op refresh report");
        let (old_ts, new_ts) = (d0.host_tensors(), same.host_tensors());
        for (ot, nt) in old_ts.iter().zip(&new_ts) {
            assert!(ot.same_payload(nt),
                    "{mode:?}: no-op refresh re-allocated a payload");
        }
        // a real update: delta build == full build, bit for bit, with the
        // untouched tensors skipped in the report
        let full1 = rt.engine_weights(mode, &p1).unwrap();
        let (d1, r1) = rt.engine_weights_delta(mode, &p1, Some(&d0)).unwrap();
        assert_weights_bits_eq(&d1, &full1, &format!("{mode:?} update"));
        assert_eq!(r1.total(), n_tensors);
        assert!(r1.tensors_changed >= 1, "{mode:?}: update not detected");
        assert!(r1.tensors_skipped >= 1,
                "{mode:?}: untouched tensors re-staged (changed {})",
                r1.tensors_changed);
        // end to end: a mid-run hot swap of the delta build serves the
        // exact rollouts the full build does
        if mode != QuantMode::Bf16 {
            assert_eq!(rollout(&full0, &d1), rollout(&full0, &full1),
                       "{mode:?}: delta-built swap diverged from full");
        }
    }
}

/// The zero-restage guarantee, byte-exact on the real artifacts: swapping
/// in a delta build whose tensors ALL requantized identically books zero
/// swap bytes and the next decode stages only the per-tick control
/// vectors; a partial delta (section A changed, quantized section B
/// masked) books and stages exactly the changed payload — strictly less
/// than the full weight restage the pre-delta path paid.
#[test]
fn zero_change_delta_swap_restages_nothing() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let p = rt.init_params(72).unwrap();
    let (w0, _) = rt.engine_weights_delta(QuantMode::Int8, &p, None).unwrap();
    // same params → every Arc reused
    let (w1, r1) = rt
        .engine_weights_delta(QuantMode::Int8, &p, Some(&w0))
        .unwrap();
    assert_eq!((r1.tensors_changed, r1.tensors_skipped),
               (0, man.params.len()));
    // section A perturbed, section B untouched → only `a` re-stages
    let mut pa = p.clone();
    for v in &mut pa[..man.a_size] {
        *v += 0.5;
    }
    let (w2, r2) = rt
        .engine_weights_delta(QuantMode::Int8, &pa, Some(&w1))
        .unwrap();
    assert!(r2.tensors_changed >= 1 && r2.tensors_skipped >= 1,
            "expected a mixed report, got {}/{}",
            r2.tensors_changed, r2.tensors_skipped);
    let (tokens, _, plens) = test_prompts(&rt, 1);
    let prompt = tokens[..plens[0]].to_vec();
    let mut eng = StepEngine::new(&rt, w0);
    let wb = eng.weight_bytes();
    let logits = eng.prefill(&[0], &[prompt.as_slice()]).unwrap();
    let mut tok = greedy_tok(logits[0].as_slice());
    let mut pos = (prompt.len() - 1) as i32;
    let step = |eng: &mut StepEngine, tok: &mut i32, pos: &mut i32| {
        *pos += 1;
        assert!((*pos as usize) + 1 < man.max_seq, "test prompt too long");
        let lg = eng.decode(&[(0, *pos, *tok)]).unwrap();
        *tok = greedy_tok(lg[0].as_slice());
    };
    // drain the post-prefill KV re-stage; no swap has happened yet
    step(&mut eng, &mut tok, &mut pos);
    eng.take_transfer();
    assert_eq!(eng.take_swap_h2d(), 0);
    let control = (2 * 4 * man.rollout_batch) as u64;
    // ZERO-CHANGE swap: pointer-equal payloads keep their handles — the
    // ledger books nothing and the next decode is control-vector-only
    eng.swap_weights(w1, 1);
    assert_eq!(eng.take_swap_h2d(), 0, "zero-change swap booked a restage");
    step(&mut eng, &mut tok, &mut pos);
    let (h2d, _) = eng.take_transfer();
    assert_eq!(h2d, control,
               "zero-change swap restaged weight bytes ({h2d} vs {control})");
    // PARTIAL swap: exactly the section-A payload re-stages, byte-exact,
    // strictly cheaper than the full restage
    eng.swap_weights(w2, 2);
    let booked = eng.take_swap_h2d();
    let a_bytes = (man.a_size * 4) as u64;
    assert_eq!(booked, a_bytes,
               "partial swap booked {booked} bytes, expected the \
                section-A payload {a_bytes}");
    assert!(booked < wb, "partial restage not cheaper than full ({wb})");
    step(&mut eng, &mut tok, &mut pos);
    let (h2d, _) = eng.take_transfer();
    assert_eq!(h2d, control + a_bytes,
               "partial swap staged {h2d}; expected control + changed \
                payload ({})", control + a_bytes);
}

/// Prune-as-you-generate on the real artifacts: on a DAPO-shaped workload
/// where >= 1/3 of the groups are reward-uniform, the service path (shared
/// prefill + in-flight pruning) decodes strictly fewer tokens and prefills
/// strictly fewer rows than the PR-1 per-request scheduler behavior on the
/// identical submissions, without ever dropping a group.
#[test]
fn service_pruning_saves_decode_with_artifacts() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let params = rt.init_params(37).unwrap();
    let w = rt.engine_weights(QuantMode::Int8, &params).unwrap();
    let (n_groups, g) = (6usize, 4usize);
    let (tokens, _, plens) = test_prompts(&rt, n_groups);
    let s = man.max_seq;
    let run = |payg: bool| {
        let mut svc = RolloutService::new(
            vec![StepEngine::new(&rt, w.clone())], man.max_seq, man.eos_id);
        svc.set_share_prefix(payg);
        svc.prune = if payg {
            PrunePolicy::online(2)
        } else {
            PrunePolicy::off()
        };
        for (gid, &plen) in plens.iter().enumerate() {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: tokens[gid * s..gid * s + plen].to_vec(),
                group_size: g,
                max_new: man.max_new.min(24),
                temperature: 1.0,
                top_p: 1.0,
                seed: 0xAB ^ ((gid as u64) << 8),
            });
        }
        // groups 0, 3 uniform (uninformative); others vary per member
        let results = svc
            .run(|gid, res| if gid % 3 == 0 {
                1.0
            } else {
                (res.generated.len() % 2) as f32
            })
            .unwrap();
        assert_eq!(results.len(), n_groups);
        (svc.take_stats().unwrap(), results)
    };
    let (service, service_res) = run(true);
    let (plain, plain_res) = run(false);
    assert!(plain_res.iter().all(|r| r.complete()));
    assert_eq!(service.completed + service.cancelled, service.submitted);
    // fork savings are structural: every group's siblings share one
    // prefill row, so rows drop ~group_size x whenever siblings co-admit
    assert!(service.prefill_rows < plain.prefill_rows,
            "prefix sharing saved no prefill rows: {} vs {}",
            service.prefill_rows, plain.prefill_rows);
    // every ADMITTED request was either prefilled or forked; requests
    // cancelled while still queued never admit, so the sum is bracketed by
    // the cancellation count rather than equal to submitted
    assert!(service.prefill_rows + service.forked <= service.submitted);
    assert!(service.prefill_rows + service.forked
            >= service.submitted - service.cancelled);
    assert!(service.prefill_calls <= plain.prefill_calls);
    assert_eq!(plain.prefill_rows, plain.submitted);
    // pruning savings depend on staggered finishes (EOS variance); when a
    // member was cancelled mid-flight the saving must be real.  The
    // guaranteed-savings assertion on a high-variance workload lives in
    // tests/properties.rs::service_prunes_and_forks_beat_plain_scheduler.
    assert!(service.generated_tokens <= plain.generated_tokens);
    if service.cancelled > 0 {
        assert!(service.generated_tokens < plain.generated_tokens,
                "cancellations but no decode-token saving: {} vs {}",
                service.generated_tokens, plain.generated_tokens);
        assert!(service_res.iter().any(|r| r.pruned));
    }
}

/// KV-capacity boundary through the real artifacts: a request sized to the
/// exact context edge (prompt_len + max_new == max_seq) must complete with
/// no out-of-range decode position (StepEngine::decode asserts pos <
/// max_seq) and never emit past the context.
#[test]
fn scheduler_context_boundary_with_artifacts() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let params = rt.init_params(23).unwrap();
    let w = rt.engine_weights(QuantMode::Int8, &params).unwrap();
    let (tokens, _, plens) = test_prompts(&rt, 2);
    let mut engine = StepEngine::new(&rt, w);
    let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
    let s = man.max_seq;
    for (r, &plen) in plens.iter().enumerate() {
        sched.submit(RolloutRequest {
            id: r as u64,
            prompt: Arc::new(tokens[r * s..r * s + plen].to_vec()),
            // exactly to the context edge (larger than the fused max_new)
            max_new: man.max_seq - plen,
            temperature: 0.0,
            top_p: 1.0,
            seed: r as u64,
        });
    }
    let results = sched.run_to_completion().unwrap();
    assert_eq!(results.len(), plens.len());
    assert_eq!(sched.stats.completed, sched.stats.submitted);
    for res in &results {
        let plen = plens[res.id as usize];
        assert!(!res.generated.is_empty());
        assert!(plen + res.generated.len() <= man.max_seq,
                "generation past the context edge");
    }
}

/// Rust quantizer mirrors must agree with the quantize artifacts bit-for-bit
/// (int8 codes exactly; fp8 within 1 ulp of the scale multiply).
#[test]
fn quant_mirrors_match_artifacts() {
    let rt = runtime();
    let params = rt.init_params(9).unwrap();
    let man = rt.manifest().clone();
    let flat_b = &params[man.a_size..];
    let (qw_art, qs_art) = rt.quantize_int8(flat_b).unwrap();
    let fq_art = rt.quantize_fp8(flat_b).unwrap();
    analysis::for_each_mat(&man, |name, off, k, n| {
        let w = &flat_b[off..off + k * n];
        let (qw, qs) = qint8::weight_quant(w, k, n);
        assert_eq!(&qw_art[off..off + k * n], &qw[..], "int8 codes {name}");
        let scale_off = man
            .qscales
            .iter()
            .find(|sc| sc.name == name)
            .unwrap()
            .offset;
        for (a, b) in qs_art[scale_off..scale_off + n].iter().zip(&qs) {
            assert!((a - b).abs() <= 1e-6 * b.abs(), "{name} scale");
        }
        // fp8: exponent extraction via log2 differs between XLA's fast log
        // and Rust libm by one ulp at rare power-of-2 boundaries, moving a
        // value one grid step (measured: 1 of 786k values on init params).
        // Require agreement everywhere except <= 0.01% boundary ties, each
        // within one mantissa step (12.5% relative).
        let fq = qfp8::weight_quant(w, k, n);
        let mut bad = 0usize;
        for (a, b) in fq_art[off..off + k * n].iter().zip(&fq) {
            let d = (a - b).abs();
            if d > 2e-6 * b.abs().max(1e-4) {
                assert!(d <= 0.13 * b.abs().max(1e-6),
                        "{name} fp8 off-grid: {a} vs {b}");
                bad += 1;
            }
        }
        assert!(bad * 10_000 <= k * n, "{name}: {bad} fp8 boundary ties");
    });
}

/// UAQ: artifact equals the host mirror, output is invariant, and the INT8
/// quantization error on scaled matrices shrinks ~s^2 (Eq. 12).
#[test]
fn uaq_artifact_and_invariance() {
    let rt = runtime();
    let params = rt.init_params(11).unwrap();
    let man = rt.manifest().clone();
    let scaled = rt.uaq_scale(&params, 1.5).unwrap();
    let mut host = params.clone();
    analysis::uaq_scale_host(&man, &mut host, 1.5);
    for (i, (a, b)) in scaled.iter().zip(&host).enumerate() {
        assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-6), "idx {i}");
    }
    // invariance: teacher-forced logprobs unchanged
    let (tokens, _, _) = test_prompts(&rt, 8);
    let lp0 = rt.score_bf16(&params, &tokens).unwrap().logprob;
    let lp1 = rt.score_bf16(&scaled, &tokens).unwrap().logprob;
    let max: f32 = lp0
        .iter()
        .zip(&lp1)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max < 5e-4, "UAQ broke invariance: {max}");
    // Scale-invariance of symmetric absmax quantization: Q(W/s)*s == Q(W)
    // exactly at init — identical int8 codes, scales divided by s.  (UAQ's
    // benefit is on the TRAINING trajectory: the absolute quantization grid
    // is s-times finer against Adam-sized updates — Eq. 12; measured in
    // benches/fig9_weight_change.rs via int8_code_change_frac.)
    let (qw0, qs0) = rt.quantize_int8(&params[man.a_size..]).unwrap();
    let (qw1, qs1) = rt.quantize_int8(&scaled[man.a_size..]).unwrap();
    // mathematically identical; f32 rounding of W/s can flip values sitting
    // exactly on rounding boundaries by one code — allow < 0.1% of them
    let flips = qw0
        .iter()
        .zip(&qw1)
        .filter(|(a, b)| a != b)
        .inspect(|(a, b)| assert!((**a as i16 - **b as i16).abs() <= 1))
        .count();
    assert!(flips * 1000 <= qw0.len(),
            "UAQ flipped {flips}/{} int8 codes", qw0.len());
    let mut scaled_channels = 0usize;
    for sc in &man.qscales {
        let is_scaled = sc.name.contains("qkv") || sc.name.contains("mlp_up");
        for j in 0..sc.channels {
            let (a, b) = (qs0[sc.offset + j], qs1[sc.offset + j]);
            let expect = if is_scaled { a / 1.5 } else { a };
            assert!((b - expect).abs() <= 1e-6 * a.abs(),
                    "{} channel {j}: {a} -> {b}", sc.name);
        }
        if is_scaled {
            scaled_channels += sc.channels;
        }
    }
    assert!(scaled_channels > 0);
    // absolute quantization grid on the network function is finer: the
    // scaled matrices' quant steps shrank by s while the LN gain re-amplifies
    // the signal — so a fixed-size weight update now crosses code boundaries
    // s-times more often.
}

/// train_step objective flags: ACR must pass more positive-advantage tokens
/// than TIS when behavior is truncated, and naive-quant must differ from
/// decoupled variants.  Cross-checks artifact metrics against the host
/// surrogate reference.
#[test]
fn train_step_objective_flags() {
    let rt = runtime();
    let params = rt.init_params(13).unwrap();
    let man = rt.manifest().clone();
    let (b, t) = (man.train_batch, man.max_seq);
    let (tokens, _, _) = test_prompts(&rt, 16);
    let sc = rt.score_bf16(&params, &tokens).unwrap();
    let mut mask = vec![0.0f32; b * t];
    for r in 0..16 {
        for c in 10..40 {
            mask[r * t + c] = 1.0;
        }
    }
    // craft a behavior policy with heavy truncation (rho up to e^3)
    let mut lp_behav = sc.logprob.clone();
    for (i, &m) in mask.iter().enumerate() {
        if m > 0.5 {
            lp_behav[i] -= ((i % 7) as f32) * 0.5;
        }
    }
    let adv = vec![0.5f32; b * t];
    let zeros = vec![0.0f32; b * t];
    let mk_batch = || TrainBatch {
        tokens: tokens.clone(),
        mask: mask.clone(),
        adv: adv.clone(),
        lp_behav: lp_behav.clone(),
        lp_prox: sc.logprob.clone(),
        lp_ref: sc.logprob.clone(),
        returns: zeros.clone(),
        old_values: zeros.clone(),
    };
    let mut losses = Vec::new();
    for kind in [ObjectiveKind::OnPolicy, ObjectiveKind::NaiveQuant,
                 ObjectiveKind::Decoupled, ObjectiveKind::Tis,
                 ObjectiveKind::Acr] {
        let obj = Objective { kind, lr: 0.0, tis_cap: 2.0,
                              ..Objective::default() };
        let mut ps = ParamStore::new(&man, params.clone());
        let mets = rt
            .train_step(&mut ps, &mk_batch(), &obj.to_flags(&man.flags))
            .unwrap();
        assert!(mets.iter().all(|m| m.is_finite()), "{kind:?}");
        losses.push(mets[0]);
        // truncation is active by construction
        if kind == ObjectiveKind::Tis || kind == ObjectiveKind::Acr {
            let trunc = mets[10];
            assert!(trunc > 0.1, "{kind:?} trunc_frac {trunc}");
        }
        // lr=0: params unchanged
        assert_eq!(ps.params, params);
    }
    // the variants must produce distinct losses
    let mut uniq = losses.clone();
    uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
    uniq.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
    assert!(uniq.len() >= 4, "losses {losses:?}");
    // ACR surrogate >= TIS surrogate (loss = -surrogate + ...) with
    // positive advantages: ACR loss <= TIS loss
    assert!(losses[4] <= losses[3] + 1e-6,
            "ACR {} vs TIS {}", losses[4], losses[3]);
}

/// Generation determinism: same seed -> identical rollout; different seed
/// -> different sampling.
#[test]
fn generate_deterministic_by_seed() {
    let rt = runtime();
    let params = rt.init_params(17).unwrap();
    let w = rt.engine_weights(QuantMode::Fp8, &params).unwrap();
    let (tokens, lens, _) = test_prompts(&rt, 10);
    let a = rt.generate(&w, &tokens, &lens, 123, 1.0, 0.9).unwrap();
    let b = rt.generate(&w, &tokens, &lens, 123, 1.0, 0.9).unwrap();
    let c = rt.generate(&w, &tokens, &lens, 124, 1.0, 0.9).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.logprob, b.logprob);
    assert_ne!(a.tokens, c.tokens);
}

/// init_params determinism across calls + section sizes from the manifest.
#[test]
fn init_params_contract() {
    let rt = runtime();
    let man = rt.manifest().clone();
    let a = rt.init_params(0).unwrap();
    let b = rt.init_params(0).unwrap();
    let c = rt.init_params(1).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert_eq!(a.len(), man.n_params);
    // ln gains initialized to 1 (section A sanity via manifest offsets)
    let ln = man.param("layer0.ln1").unwrap();
    for &x in &a[ln.offset..ln.offset + ln.numel()] {
        assert_eq!(x, 1.0);
    }
}
