//! Tier-1 gate: the repo's own source tree must pass every `qurl lint`
//! pass.  This is the test-side twin of the `qurl lint` subcommand — it
//! makes catalog drift, config drift, protocol gaps, hot-path panics,
//! and Send-safety violations `cargo test` failures, not just CI-job
//! failures.  Per-pass semantics (and the seeded-violation fixtures)
//! are covered by the unit tests in `src/analysis/passes.rs`; this file
//! only asserts the live tree is clean.

use std::path::Path;

use qurl::analysis::{report, run_all, SourceSet};

#[test]
fn repo_source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let set = SourceSet::load(&root).expect("scan src/");
    let findings = run_all(&set);
    assert!(findings.is_empty(), "\n{}", report(&findings));
}
