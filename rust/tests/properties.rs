//! Property-based tests (propcheck) over coordinator + RL invariants.
//! These run without artifacts — pure host logic.

use std::sync::Arc;

use qurl::coordinator::{EngineFactory, FinishReason, GroupSpec, KvConfig,
                        KvLayout, KvPager, MockEngine, PageAllocator,
                        PlacementLog, PrunePolicy, RolloutRequest,
                        RolloutService, Scheduler, SlotMap, StealPolicy,
                        StripePolicy};
use qurl::rl::advantage;
use qurl::rl::dapo;
use qurl::rl::objective::{surrogate_token, Objective, ObjectiveKind};
use qurl::tasks::{Family, Tokenizer, ALL_FAMILIES};
use qurl::util::propcheck::{assert_prop, F64In, Pair, UsizeIn, VecOf};
use qurl::util::rng::Pcg64;

/// Slot allocator: any acquire/release trace preserves the partition
/// invariant and never double-allocates.
#[test]
fn prop_slotmap_partition() {
    // value = (capacity, ops) where op < 2*cap: acquire (op < cap) or
    // release the op-cap-th active slot
    let g = Pair(UsizeIn(1, 16), VecOf(UsizeIn(0, 31), 0, 200));
    assert_prop("slotmap-partition", 0xA11, 300, &g, |(cap, ops)| {
        let cap = (*cap).max(1);
        let mut sm = SlotMap::new(cap);
        let mut active: Vec<(usize, u64)> = Vec::new();
        let mut next_id = 0u64;
        for &op in ops {
            if op % 2 == 0 {
                if let Some(slot) = sm.acquire(next_id) {
                    if active.iter().any(|&(s, _)| s == slot) {
                        return false; // double allocation!
                    }
                    active.push((slot, next_id));
                    next_id += 1;
                }
            } else if !active.is_empty() {
                let (slot, id) = active.remove(op % active.len());
                sm.release(slot, id);
            }
            if !sm.check_invariants() {
                return false;
            }
            if sm.active_count() != active.len() {
                return false;
            }
        }
        true
    });
}

/// Scheduler + mock engine over random request mixes, capacities and
/// admission thresholds: every submitted request completes exactly once,
/// mean occupancy never exceeds 1, per-request token budgets are honored,
/// and no decode position reaches the KV capacity (the mock asserts).
#[test]
fn prop_scheduler_serves_all_requests() {
    let max_seq = 16usize;
    // ((slots, min_prefill_batch), [(prompt_len, max_new); n])
    let g = Pair(Pair(UsizeIn(1, 8), UsizeIn(1, 3)),
                 VecOf(Pair(UsizeIn(1, 6), UsizeIn(1, 10)), 0, 24));
    assert_prop("scheduler-serves-all", 0x5C4ED, 120, &g,
                |((slots, minb), reqs)| {
        let mut eng = MockEngine::new((*slots).max(1), 8, max_seq, 2);
        let mut sched = Scheduler::new(&mut eng, max_seq, 2);
        sched.min_prefill_batch = (*minb).max(1);
        for (i, &(plen, max_new)) in reqs.iter().enumerate() {
            sched.submit(RolloutRequest {
                id: i as u64,
                prompt: Arc::new((0..plen.clamp(1, max_seq - 1))
                    .map(|k| 3 + (k as i32 % 5))
                    .collect()),
                max_new: max_new.max(1),
                temperature: 0.0,
                top_p: 1.0,
                seed: i as u64,
            });
        }
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        if results.len() != reqs.len()
            || sched.stats.completed != sched.stats.submitted
            || sched.stats.submitted != reqs.len()
            || sched.stats.mean_occupancy() > 1.0 + 1e-9
        {
            return false;
        }
        for (i, r) in results.iter().enumerate() {
            if r.id != i as u64 || r.generated.is_empty() {
                return false; // lost, duplicated or empty request
            }
            if r.generated.len() > reqs[i].1.max(1) {
                return false; // max_new overrun
            }
            if r.generated.len() != r.logprobs.len() {
                return false;
            }
        }
        true
    });
}

/// Cancellation invariants under random interleavings of ticks and
/// cancels: `completed + cancelled == submitted` on the drained scheduler,
/// a cancelled request never appears in tick results, every slot is
/// recycled (free capacity fully restored), and cancel() itself returns
/// the partial exactly once (double-cancel is None).
#[test]
fn prop_scheduler_cancellation_invariants() {
    let max_seq = 16usize;
    // ((slots, n_requests), [op; m]) — op even: tick, odd: cancel id op/2
    let g = Pair(Pair(UsizeIn(1, 6), UsizeIn(1, 20)),
                 VecOf(UsizeIn(0, 63), 4, 80));
    assert_prop("scheduler-cancel", 0xCA7CE1, 150, &g,
                |((slots, n_req), ops)| {
        let slots = (*slots).max(1);
        let n_req = (*n_req).max(1);
        let mut eng = MockEngine::new(slots, 8, max_seq, 2);
        let mut sched = Scheduler::new(&mut eng, max_seq, 2);
        for i in 0..n_req {
            sched.submit(RolloutRequest {
                id: i as u64,
                prompt: Arc::new((0..1 + i % 5).map(|k| 3 + (k as i32 % 5))
                    .collect()),
                max_new: 1 + i % 8,
                temperature: 0.0,
                top_p: 1.0,
                seed: i as u64,
            });
        }
        let mut completed: Vec<u64> = Vec::new();
        let mut cancelled: Vec<u64> = Vec::new();
        for &op in ops {
            if op % 2 == 0 {
                completed.extend(sched.tick().unwrap().iter().map(|r| r.id));
            } else {
                let id = (op / 2) as u64 % n_req as u64;
                if let Some(partial) = sched.cancel(id) {
                    if partial.finish != FinishReason::Cancelled {
                        return false;
                    }
                    cancelled.push(id);
                    // a second cancel of the same id must be a no-op
                    if sched.cancel(id).is_some() {
                        return false;
                    }
                }
            }
        }
        completed.extend(sched.run_to_completion().unwrap()
                         .iter().map(|r| r.id));
        // ledger: every request resolved exactly once, never both ways
        if completed.len() + cancelled.len() != n_req {
            return false;
        }
        if sched.stats.completed + sched.stats.cancelled
            != sched.stats.submitted
        {
            return false;
        }
        if completed.iter().any(|id| cancelled.contains(id)) {
            return false; // cancelled request leaked into results
        }
        let mut all: Vec<u64> = completed.iter().chain(&cancelled).copied()
            .collect();
        all.sort_unstable();
        all.dedup();
        all.len() == n_req // no duplicates either way
    });
}

/// Page-allocator ledger over random acquire/alias/release/write traces:
/// the free list always partitions against the live refcounts, a write
/// into a shared page must go through CoW (and the CoW result is always
/// private), and after dropping every held reference the allocator drains
/// with `freed == allocated` and zero active pages.
#[test]
fn prop_page_allocator_ledger_balances() {
    // (budget, ops) — op % 4: 0 acquire, 1 alias a held ref, 2 drop a
    // held ref, 3 write into a held ref (CoW first iff shared)
    let g = Pair(UsizeIn(0, 12), VecOf(UsizeIn(0, 255), 0, 160));
    assert_prop("page-allocator-ledger", 0x9A6E, 250, &g, |(budget, ops)| {
        let mut pa = PageAllocator::new(*budget);
        let mut held: Vec<u32> = Vec::new();
        for &op in ops {
            match op % 4 {
                0 => held.push(pa.acquire_grow()),
                1 if !held.is_empty() => {
                    let p = held[(op / 4) % held.len()];
                    pa.alias(p);
                    held.push(p);
                }
                2 if !held.is_empty() => {
                    let p = held.swap_remove((op / 4) % held.len());
                    pa.release(p);
                }
                3 if !held.is_empty() => {
                    let i = (op / 4) % held.len();
                    let p = held[i];
                    if pa.is_shared(p) {
                        // refcounted pages are never written in place:
                        // the write path detaches a private copy first
                        held[i] = pa.cow(p);
                        if pa.is_shared(held[i]) {
                            return false; // CoW result must be private
                        }
                    }
                }
                _ => {}
            }
            if !pa.check_invariants() {
                return false;
            }
            if pa.active_pages() > pa.high_water() {
                return false;
            }
        }
        for p in held {
            pa.release(p);
        }
        let st = pa.peek_stats();
        pa.drained()
            && pa.check_invariants()
            && st.freed == st.allocated
            && st.active == 0
            && st.high_water as u64 <= st.allocated
    });
}

/// Pager-level CoW proof: after forking a prefilled prompt into sibling
/// slots, every page the pager hands decode to write (`on_decode`'s
/// return) has refcount exactly 1 — shared prompt pages are detached, not
/// mutated — and releasing all slots (twice: release is idempotent)
/// drains the ledger with the alias savings on record.
#[test]
fn prop_pager_cow_never_writes_shared_pages() {
    let max_seq = 32usize;
    // ((page_size, prompt_len), [(fork_bit, decode_steps); n])
    let g = Pair(Pair(UsizeIn(1, 9), UsizeIn(1, 12)),
                 VecOf(Pair(UsizeIn(0, 1), UsizeIn(0, 10)), 1, 6));
    assert_prop("pager-cow-private", 0xC0B7, 250, &g,
                |((page, plen), members)| {
        let page = (*page).max(1);
        let plen = (*plen).clamp(1, max_seq / 2);
        let slots = members.len() + 1;
        let mut pg = KvPager::new(slots, max_seq, KvConfig {
            layout: KvLayout::Paged,
            page_size: page,
            budget_pages: None,
        });
        pg.on_prefill(0, plen);
        for (i, &(forked, _)) in members.iter().enumerate() {
            if forked == 1 {
                pg.on_fork(0, &[i + 1], plen);
            } else {
                pg.on_prefill(i + 1, plen);
            }
        }
        if !pg.check_invariants() {
            return false;
        }
        // lockstep decode growth across members, like the scheduler drives
        for step in 0..10usize {
            let pos = plen + step;
            if pos >= max_seq {
                break;
            }
            for (i, &(_, steps)) in members.iter().enumerate() {
                if step < steps {
                    match pg.on_decode(i + 1, pos) {
                        Some(p) => {
                            if pg.allocator().ref_count(p) != 1 {
                                return false; // about to write a shared page
                            }
                        }
                        None => return false, // paged must name the page
                    }
                }
            }
            if !pg.check_invariants() {
                return false;
            }
        }
        for s in 0..slots {
            pg.on_release(s);
        }
        for s in 0..slots {
            pg.on_release(s); // idempotent: double-release is a no-op
        }
        let st = pg.peek_stats();
        if members.iter().any(|&(f, _)| f == 1) && st.shared == 0 {
            return false; // forks must register alias savings
        }
        pg.drained() && pg.check_invariants()
    });
}

/// Paged KV under random cancel/tick interleavings, page sizes, budgets
/// and chunked prefill: identical prompts fork (alias) pages, cancels and
/// the final drain return every non-shared page, and the engine-side
/// pager ends leak-free — `freed == allocated`, zero active pages.
#[test]
fn prop_paged_scheduler_cancel_interleavings_leak_free() {
    let max_seq = 16usize;
    // (((slots, n_requests), (page_size, budget_sel)), [op; m])
    let g = Pair(Pair(Pair(UsizeIn(1, 6), UsizeIn(1, 16)),
                      Pair(UsizeIn(1, 6), UsizeIn(0, 2))),
                 VecOf(UsizeIn(0, 63), 4, 70));
    assert_prop("paged-cancel-leak-free", 0xFACE5, 120, &g,
                |(((slots, n_req), (page, budget)), ops)| {
        let slots = (*slots).max(1);
        let n_req = (*n_req).max(1);
        let page = (*page).max(1);
        let mut eng = MockEngine::new(slots, 8, max_seq, 2);
        {
            let mut sched = Scheduler::new(&mut eng, max_seq, 2);
            sched.set_kv(KvConfig {
                layout: KvLayout::Paged,
                page_size: page,
                budget_pages: match *budget {
                    0 => None,
                    b => Some(b * slots * 2), // tight: admission gates bind
                },
            });
            sched.prefill_chunk = page % 3; // mix whole and chunked prefill
            let prompt = Arc::new(vec![3, 4, 5, 6]);
            for i in 0..n_req {
                sched.submit(RolloutRequest {
                    id: i as u64,
                    prompt: prompt.clone(), // identical: co-admissions fork
                    max_new: 1 + i % 8,
                    temperature: 0.0,
                    top_p: 1.0,
                    seed: i as u64,
                });
            }
            for &op in ops {
                if op % 2 == 0 {
                    sched.tick().unwrap();
                } else {
                    let id = (op / 2) as u64 % n_req as u64;
                    // double-cancel must be a no-op (no double-free)
                    if sched.cancel(id).is_some()
                        && sched.cancel(id).is_some()
                    {
                        return false;
                    }
                }
            }
            sched.run_to_completion().unwrap();
            let st = sched.take_stats();
            if st.completed + st.cancelled != st.submitted {
                return false;
            }
            if st.kv_pages_freed != st.kv_pages_allocated {
                return false; // leaked or double-freed pages
            }
            if st.kv_pages_active != 0 {
                return false;
            }
        }
        eng.pager().drained() && eng.pager().check_invariants()
    });
}

/// Dense is the parity oracle for paged, across a mid-run weight swap:
/// submit, tick a random number of times, hot-swap weights, drain — the
/// paged run (same chunk setting, unbounded budget, so admission timing
/// is identical) must be bit-identical to the dense run in tokens,
/// logprob bits and finish reasons.
#[test]
fn prop_paged_matches_dense_across_mid_run_swap() {
    let max_seq = 16usize;
    // ((page_size, prefill_chunk), (ticks_before_swap, n_requests))
    let g = Pair(Pair(UsizeIn(1, 6), UsizeIn(0, 3)),
                 Pair(UsizeIn(0, 6), UsizeIn(1, 10)));
    assert_prop("paged-swap-parity", 0x5AB9, 150, &g,
                |((page, chunk), (ticks, n_req))| {
        let n_req = (*n_req).max(1);
        let run = |layout: KvLayout| {
            let mut eng = MockEngine::new(3, 8, max_seq, 2);
            let mut sched = Scheduler::new(&mut eng, max_seq, 2);
            sched.set_kv(KvConfig {
                layout,
                page_size: (*page).max(1),
                // unbounded: the page gate must not change admission
                // timing, else the swap lands at different positions
                budget_pages: None,
            });
            sched.prefill_chunk = *chunk; // same chunk in both runs
            let prompt = Arc::new(vec![3, 4, 5, 6, 3]);
            let mut out = Vec::new();
            for i in 0..n_req {
                sched.submit(RolloutRequest {
                    id: i as u64,
                    prompt: prompt.clone(),
                    max_new: 2 + i % 7,
                    temperature: if i % 2 == 0 { 0.0 } else { 0.9 },
                    top_p: 1.0,
                    seed: i as u64,
                });
            }
            for _ in 0..*ticks {
                out.extend(sched.tick().unwrap());
            }
            sched.swap_weights(0xFEED_C0DE, 1); // hot requant mid-flight
            out.extend(sched.run_to_completion().unwrap());
            out.sort_by_key(|r| r.id);
            out.iter()
                .map(|r| (r.id,
                          r.generated.clone(),
                          r.logprobs.iter().map(|l| l.to_bits())
                              .collect::<Vec<u32>>(),
                          r.finish))
                .collect::<Vec<_>>()
        };
        run(KvLayout::Dense) == run(KvLayout::Paged)
    });
}

/// Full-run paged/dense parity across execution backends and stripe
/// policies: with a TIGHT page budget (admission timing differs from
/// dense — that's allowed; completed outputs must not) plus chunked
/// prefill, the paged inline and paged threaded services produce
/// bit-identical rollouts to the dense inline oracle, and the paged
/// ledger drains leak-free.
#[test]
fn prop_paged_matches_dense_across_backends_and_stripes() {
    let max_seq = 16usize;
    type Key = (Vec<i32>, Vec<u32>, FinishReason, Option<u32>);
    // ((engines, slots), ((page_size, prefill_chunk), [(size, temp); n]))
    let g = Pair(Pair(UsizeIn(1, 3), UsizeIn(1, 4)),
                 Pair(Pair(UsizeIn(1, 6), UsizeIn(0, 3)),
                      VecOf(Pair(UsizeIn(1, 4), UsizeIn(0, 1)), 1, 6)));
    assert_prop("paged-dense-backend-parity", 0xBA6ED, 40, &g,
                |((engines, slots), ((page, chunk), groups))| {
        let n_eng = (*engines).max(1);
        let slots = (*slots).max(1);
        let paged_cfg = KvConfig {
            layout: KvLayout::Paged,
            page_size: (*page).max(1),
            budget_pages: Some(4), // tight enough that the gate binds
        };
        let fingerprint = |svc: &mut RolloutService<MockEngine>|
                          -> Vec<Key> {
            for (gid, &(sz, temp)) in groups.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    max_new: 1 + gid % 9,
                    temperature: temp as f32,
                    top_p: 1.0,
                    seed: 0xE1 ^ ((gid as u64) << 8),
                });
            }
            let results = svc
                .run(|gid, res| (gid % 2) as f32
                     + (res.generated.len() % 3) as f32)
                .unwrap();
            results
                .iter()
                .flat_map(|gr| gr.members.iter().map(|m| {
                    (m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits())
                         .collect::<Vec<u32>>(),
                     m.result.finish,
                     m.reward.map(|r| r.to_bits()))
                }))
                .collect()
        };
        let inline = |n: usize| -> RolloutService<MockEngine> {
            let engs: Vec<MockEngine> = (0..n)
                .map(|_| MockEngine::new(slots, 8, max_seq, 2))
                .collect();
            RolloutService::new(engs, max_seq, 2)
        };
        let threaded = |n: usize| -> RolloutService<MockEngine> {
            let fs: Vec<EngineFactory<MockEngine>> = (0..n)
                .map(|_| {
                    Box::new(move || Ok(MockEngine::new(slots, 8, max_seq,
                                                        2)))
                        as EngineFactory<MockEngine>
                })
                .collect();
            RolloutService::threaded(fs, max_seq, 2).unwrap()
        };
        for stripe in [StripePolicy::RoundRobin, StripePolicy::LeastLoaded] {
            let mut dense = inline(n_eng);
            dense.stripe = stripe; // dense oracle: default KvConfig
            let fd = fingerprint(&mut dense);
            let mut paged = inline(n_eng);
            paged.stripe = stripe;
            paged.set_kv(paged_cfg);
            paged.set_prefill_chunk(*chunk);
            let fp = fingerprint(&mut paged);
            let mut pthr = threaded(n_eng);
            pthr.stripe = stripe;
            pthr.set_kv(paged_cfg);
            pthr.set_prefill_chunk(*chunk);
            let ft = fingerprint(&mut pthr);
            if fd != fp || fd != ft {
                return false; // page layout changed completed outputs
            }
            let st = paged.take_stats().unwrap();
            if st.kv_pages_freed != st.kv_pages_allocated {
                return false; // gated admission leaked pages
            }
        }
        true
    });
}

/// The headline QuRL serving win, asserted end-to-end on the mock engine:
/// on a DAPO-shaped workload where >= 1/3 of the groups are uninformative
/// (uniform reward), the reward-aware service path — group-shared fork_kv
/// prefill + in-flight pruning — must decode strictly fewer tokens, issue
/// strictly fewer prefill calls AND strictly fewer prefill rows than the
/// PR-1 per-request scheduler path (share_prefix off, no pruning) on the
/// identical workload.
#[test]
fn service_prunes_and_forks_beat_plain_scheduler() {
    let max_seq = 32usize;
    let (n_groups, g, slots) = (9usize, 6usize, 4usize);
    let run = |payg: bool| {
        let engines = vec![MockEngine::new(slots, 8, max_seq, 2)];
        let mut svc = RolloutService::new(engines, max_seq, 2);
        svc.set_share_prefix(payg);
        // wave-structured admission (wait for a full slot-width batch):
        // identical wave boundaries in both runs, so the prefill-call
        // comparison measures pruning, not admission-dribble timing
        svc.set_min_prefill_batch(slots);
        svc.prune = if payg { PrunePolicy::online(2) } else {
            PrunePolicy::off()
        };
        for gid in 0..n_groups {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: (0..3 + gid % 4).map(|k| 3 + (k as i32 % 5)).collect(),
                group_size: g,
                max_new: 20,
                temperature: 1.0,
                top_p: 1.0,
                seed: 0xFEED ^ ((gid as u64) << 8),
            });
        }
        // every 3rd group uniform-rewarded (DAPO-uninformative by
        // construction); the rest vary by member outcome
        let results = svc.run(|gid, res| {
            if gid % 3 == 0 { 1.0 } else { (res.generated.len() % 2) as f32 }
        }).unwrap();
        assert_eq!(results.len(), n_groups);
        (svc.take_stats().unwrap(), results)
    };
    let (service, service_res) = run(true);
    let (plain, plain_res) = run(false);
    assert_eq!(plain.cancelled, 0);
    assert!(plain_res.iter().all(|r| r.complete()));
    assert_eq!(service.completed + service.cancelled, service.submitted);
    assert!(service.pruned_groups >= 3,
            "only {} groups pruned", service.pruned_groups);
    assert!(service_res.iter().filter(|r| r.pruned).count() >= 3);
    assert!(service.generated_tokens < plain.generated_tokens,
            "pruning saved no decode tokens: {} vs {}",
            service.generated_tokens, plain.generated_tokens);
    assert!(service.prefill_calls < plain.prefill_calls,
            "pruning+forking saved no prefill calls: {} vs {}",
            service.prefill_calls, plain.prefill_calls);
    assert!(service.prefill_rows < plain.prefill_rows,
            "prefix sharing saved no prefill rows: {} vs {}",
            service.prefill_rows, plain.prefill_rows);
    assert_eq!(plain.prefill_rows, plain.submitted);
}

/// Service invariants over random group mixes, engine counts and prune
/// policies: every group resolves with exactly `group_size` member
/// outcomes, results preserve submission order, cancelled members appear
/// only in pruned groups, and the merged ledger balances.
#[test]
fn prop_service_groups_resolve() {
    let max_seq = 16usize;
    // ((engines, slots), (prune, [group_size; n]))
    let g = Pair(Pair(UsizeIn(1, 3), UsizeIn(1, 5)),
                 Pair(UsizeIn(0, 1), VecOf(UsizeIn(1, 5), 1, 10)));
    assert_prop("service-groups-resolve", 0x5E2C, 120, &g,
                |((engines, slots), (prune, sizes))| {
        let n_eng = (*engines).max(1);
        let slots = (*slots).max(1);
        let engs: Vec<MockEngine> = (0..n_eng)
            .map(|_| MockEngine::new(slots, 8, max_seq, 2))
            .collect();
        let mut svc = RolloutService::new(engs, max_seq, 2);
        if *prune == 1 {
            svc.prune = PrunePolicy::online(2);
        }
        let mut submitted = 0usize;
        for (gid, &sz) in sizes.iter().enumerate() {
            let sz = sz.max(1);
            submitted += sz;
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                group_size: sz,
                max_new: 1 + gid % 9,
                temperature: 1.0,
                top_p: 1.0,
                seed: gid as u64,
            });
        }
        let results = svc.run(|gid, _| (gid % 2) as f32).unwrap();
        if results.len() != sizes.len() {
            return false;
        }
        for (i, (gr, &sz)) in results.iter().zip(sizes).enumerate() {
            if gr.group_id != i || gr.members.len() != sz.max(1) {
                return false;
            }
            if gr.engine != i % n_eng {
                return false; // round-robin striping broken
            }
            let n_cancelled = gr.members.iter()
                .filter(|m| m.result.finish == FinishReason::Cancelled)
                .count();
            if gr.pruned != (n_cancelled > 0) {
                return false; // pruned flag <=> a cancel actually landed
            }
            if gr.members.iter().any(|m| {
                (m.result.finish == FinishReason::Cancelled)
                    != m.reward.is_none()
            }) {
                return false; // scored <=> completed
            }
        }
        let st = svc.take_stats().unwrap();
        st.submitted == submitted
            && st.completed + st.cancelled == st.submitted
    });
}

/// Determinism under concurrency, the threaded-executor contract: over
/// random group mixes, engine counts, slot widths and temperatures, the
/// completed rollouts — tokens, logprob bits, finish reasons, rewards,
/// group resolution AND engine placement — are identical across
/// 1-worker-thread, N-worker-thread and inline execution, and across
/// rr vs least-loaded placement (outputs are engine-independent by the
/// isolation contract).  Thread interleaving may only change wall-clock.
#[test]
fn prop_threaded_and_striped_runs_bit_identical() {
    let max_seq = 16usize;
    type Key = (usize, Vec<i32>, Vec<u32>, FinishReason, Option<u32>);
    // ((engines, slots), [(group_size, temp_bit); n])
    let g = Pair(Pair(UsizeIn(1, 3), UsizeIn(1, 4)),
                 Pair(UsizeIn(0, 1), VecOf(UsizeIn(1, 5), 1, 8)));
    assert_prop("threaded-striped-parity", 0x7123D, 60, &g,
                |((engines, slots), (temp_bit, sizes))| {
        let n_eng = (*engines).max(1);
        let slots = (*slots).max(1);
        let temp = *temp_bit as f32; // greedy and sampled both covered
        let submit = |svc: &mut RolloutService<MockEngine>| {
            for (gid, &sz) in sizes.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    max_new: 1 + gid % 9,
                    temperature: temp,
                    top_p: 1.0,
                    seed: 0xA5 ^ ((gid as u64) << 8),
                });
            }
        };
        let fingerprint = |svc: &mut RolloutService<MockEngine>|
                          -> Vec<Key> {
            submit(svc);
            let results = svc
                .run(|gid, res| (gid % 2) as f32
                     + (res.generated.len() % 3) as f32)
                .unwrap();
            results
                .iter()
                .flat_map(|gr| {
                    gr.members.iter().map(move |m| {
                        (gr.engine,
                         m.result.generated.clone(),
                         m.result
                             .logprobs
                             .iter()
                             .map(|l| l.to_bits())
                             .collect::<Vec<u32>>(),
                         m.result.finish,
                         m.reward.map(|r| r.to_bits()))
                    })
                })
                .collect()
        };
        let threaded = |n: usize| -> RolloutService<MockEngine> {
            let fs: Vec<EngineFactory<MockEngine>> = (0..n)
                .map(|_| {
                    Box::new(move || Ok(MockEngine::new(slots, 8, max_seq,
                                                        2)))
                        as EngineFactory<MockEngine>
                })
                .collect();
            RolloutService::threaded(fs, max_seq, 2).unwrap()
        };
        let inline = |n: usize| -> RolloutService<MockEngine> {
            let engs: Vec<MockEngine> = (0..n)
                .map(|_| MockEngine::new(slots, 8, max_seq, 2))
                .collect();
            RolloutService::new(engs, max_seq, 2)
        };
        for stripe in [StripePolicy::RoundRobin, StripePolicy::LeastLoaded] {
            let mut a = inline(n_eng);
            a.stripe = stripe;
            let mut b = threaded(n_eng);
            b.stripe = stripe;
            // 1 worker thread (single engine) vs the same workload again
            let mut c = threaded(1);
            c.stripe = stripe;
            let (fa, fb, fc) = (fingerprint(&mut a), fingerprint(&mut b),
                                fingerprint(&mut c));
            if fa != fb {
                return false; // N threads changed outputs
            }
            // placement differs on 1 engine, outputs must not: compare
            // everything except the engine index
            let strip =
                |f: &[Key]| -> Vec<(Vec<i32>, Vec<u32>, FinishReason,
                                    Option<u32>)> {
                    f.iter()
                        .map(|(_, t, l, fr, r)| (t.clone(), l.clone(), *fr,
                                                 *r))
                        .collect()
                };
            if strip(&fa) != strip(&fc) {
                return false; // engine count changed outputs
            }
        }
        // rr vs least-loaded: outputs identical modulo placement
        let mut rr = inline(n_eng);
        rr.stripe = StripePolicy::RoundRobin;
        let mut ll = inline(n_eng);
        ll.stripe = StripePolicy::LeastLoaded;
        let (fr, fl) = (fingerprint(&mut rr), fingerprint(&mut ll));
        fr.iter().zip(&fl).all(|(a, b)| {
            (&a.1, &a.2, a.3, a.4) == (&b.1, &b.2, b.3, b.4)
        })
    });
}

/// Weight-epoch plumbing is exact, end-to-end through the service: after
/// `push_weights(w)`, a workload's outputs must be bit-identical to a
/// FRESH service whose engines had `w` pushed before any submission — and
/// different from the pre-swap outputs.  A stale conversion cache (or a
/// scheduler that forgets to hand the new weights/epoch to its engine)
/// keeps serving the old generation and fails the first comparison; an
/// over-eager cache key fails the second.  Runs across engine counts and
/// both execution backends.
#[test]
fn prop_weight_swap_outputs_track_epoch() {
    let max_seq = 16usize;
    // ((engines, threaded), [group_size; n])
    let g = Pair(Pair(UsizeIn(1, 3), UsizeIn(0, 1)),
                 VecOf(UsizeIn(1, 4), 1, 6));
    assert_prop("weight-swap-epoch", 0x5a9e, 40, &g,
                |((engines, threaded), sizes)| {
        let n_eng = (*engines).max(1);
        let build = |threaded: bool| -> RolloutService<MockEngine> {
            if threaded {
                let fs: Vec<EngineFactory<MockEngine>> = (0..n_eng)
                    .map(|_| {
                        Box::new(move || Ok(MockEngine::new(3, 8, max_seq, 2)))
                            as EngineFactory<MockEngine>
                    })
                    .collect();
                RolloutService::threaded(fs, max_seq, 2).unwrap()
            } else {
                let engs: Vec<MockEngine> = (0..n_eng)
                    .map(|_| MockEngine::new(3, 8, max_seq, 2))
                    .collect();
                RolloutService::new(engs, max_seq, 2)
            }
        };
        let workload = |svc: &mut RolloutService<MockEngine>| {
            for (gid, &sz) in sizes.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    max_new: 1 + gid % 6,
                    temperature: 0.0, // greedy: outputs are weight-determined
                    top_p: 1.0,
                    seed: gid as u64,
                });
            }
            let results = svc.run(|_, _| 0.0).unwrap();
            results
                .iter()
                .flat_map(|gr| gr.members.iter().map(|m| {
                    (m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits())
                         .collect::<Vec<u32>>())
                }))
                .collect::<Vec<_>>()
        };
        let t = *threaded == 1;
        // one service: run at epoch 0, swap, run again
        let mut svc = build(t);
        let out0 = workload(&mut svc);
        svc.push_weights(0xC0FF_EE00);
        let swapped = workload(&mut svc);
        // reference: a fresh service that only ever saw the new weights
        let mut fresh = build(t);
        fresh.push_weights(0xC0FF_EE00);
        let reference = workload(&mut fresh);
        swapped == reference && swapped != out0
    });
}

/// Delta-requantization swap accounting, end-to-end through the service:
/// pushing a weight signature identical to the installed one must book
/// ZERO swap-restage bytes (the engine kept its handles), a genuinely new
/// signature must book exactly one restage per replica, and the zero-change
/// push must not perturb outputs.  The ledger drains through
/// `Scheduler::take_stats` → `SchedulerStats::swap_bytes_h2d` — the same
/// plumbing the trainer's `sched_swap_bytes_h2d` row reads — across engine
/// counts and both execution backends.
#[test]
fn prop_zero_change_swap_stages_zero_bytes() {
    let max_seq = 16usize;
    // ((engines, threaded), [group_size; n])
    let g = Pair(Pair(UsizeIn(1, 3), UsizeIn(0, 1)),
                 VecOf(UsizeIn(1, 4), 1, 5));
    assert_prop("zero-change-swap-zero-h2d", 0xd317, 30, &g,
                |((engines, threaded), sizes)| {
        let n_eng = (*engines).max(1);
        let build = |threaded: bool| -> RolloutService<MockEngine> {
            if threaded {
                let fs: Vec<EngineFactory<MockEngine>> = (0..n_eng)
                    .map(|_| {
                        Box::new(move || Ok(MockEngine::new(3, 8, max_seq, 2)))
                            as EngineFactory<MockEngine>
                    })
                    .collect();
                RolloutService::threaded(fs, max_seq, 2).unwrap()
            } else {
                let engs: Vec<MockEngine> = (0..n_eng)
                    .map(|_| MockEngine::new(3, 8, max_seq, 2))
                    .collect();
                RolloutService::new(engs, max_seq, 2)
            }
        };
        let workload = |svc: &mut RolloutService<MockEngine>| {
            for (gid, &sz) in sizes.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![2 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    max_new: 1 + gid % 5,
                    temperature: 0.0,
                    top_p: 1.0,
                    seed: gid as u64,
                });
            }
            let results = svc.run(|_, _| 0.0).unwrap();
            results
                .iter()
                .flat_map(|gr| gr.members.iter().map(|m| {
                    (m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits())
                         .collect::<Vec<u32>>())
                }))
                .collect::<Vec<_>>()
        };
        let per_swap = (n_eng * std::mem::size_of::<u64>()) as u64;
        let mut svc = build(*threaded == 1);
        // no swap ever issued: the ledger starts (and drains) empty
        workload(&mut svc);
        if svc.take_stats().unwrap().swap_bytes_h2d != 0 {
            return false;
        }
        // a new signature re-stages once on every replica
        svc.push_weights(0xC0FF_EE00);
        let out1 = workload(&mut svc);
        if svc.take_stats().unwrap().swap_bytes_h2d != per_swap {
            return false;
        }
        // the SAME signature again: zero-change swap, zero bytes, and the
        // outputs of the following run are bit-identical
        svc.push_weights(0xC0FF_EE00);
        let out2 = workload(&mut svc);
        svc.take_stats().unwrap().swap_bytes_h2d == 0 && out1 == out2
    });
}

/// The PR-2 pruning-savings guarantee holds on the THREADED path: with
/// uniform-reward groups much wider than the slot count and an unreachable
/// EOS (every member would otherwise decode to max_new), online pruning
/// across worker threads must cancel sibling members — most of them while
/// still queued — and strictly reduce decoded tokens vs the identical
/// threaded run without pruning.
#[test]
fn threaded_pruning_cancels_across_threads_and_saves_tokens() {
    let max_seq = 128usize;
    let (n_groups, g, slots) = (4usize, 8usize, 2usize);
    let run = |prune: bool| {
        let factories: Vec<EngineFactory<MockEngine>> = (0..2)
            .map(|_| {
                Box::new(move || Ok(MockEngine::new(slots, 8, max_seq,
                                                    127 /* no eos */)))
                    as EngineFactory<MockEngine>
            })
            .collect();
        let mut svc =
            RolloutService::<MockEngine>::threaded(factories, max_seq, 127)
                .unwrap();
        svc.prune = if prune { PrunePolicy::online(2) } else {
            PrunePolicy::off()
        };
        for gid in 0..n_groups {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: vec![1, 3 + (gid as i32 % 5), 4, 5],
                group_size: g,
                max_new: 100,
                temperature: 1.0,
                top_p: 1.0,
                seed: 0xFEED ^ ((gid as u64) << 8),
            });
        }
        // every group uniform-rewarded: all prunable once 2 members finish
        let results = svc.run(|_, _| 1.0).unwrap();
        assert_eq!(results.len(), n_groups);
        for gr in &results {
            assert_eq!(gr.members.len(), g, "member lost in flight");
        }
        let tokens: usize =
            results.iter().map(|r| r.generated_tokens()).sum();
        (svc.take_stats().unwrap(), tokens)
    };
    let (pruned, pruned_tokens) = run(true);
    let (plain, plain_tokens) = run(false);
    assert_eq!(plain.cancelled, 0);
    assert_eq!(plain.completed, plain.submitted);
    assert_eq!(pruned.completed + pruned.cancelled, pruned.submitted,
               "threaded pruning unbalanced the ledger");
    // with B=2 slots and g=8, at least 6 members per group are queued or
    // mid-decode when the second finisher's reward lands; the cancel
    // directives cross the thread boundary and must recover real budget
    assert!(pruned.cancelled > 0, "no cross-thread cancel landed");
    assert!(pruned.pruned_groups > 0, "no group was pruned");
    assert!(pruned_tokens < plain_tokens,
            "threaded pruning saved no decode tokens: {pruned_tokens} vs \
             {plain_tokens}");
}

/// Work stealing never changes WHAT is generated, and its placement log
/// makes WHERE reproducible: over random group mixes with skewed decode
/// lengths (no pruning), an inline least-loaded run with `--steal idle`
/// must (a) produce the same completed outputs as the identical run with
/// stealing off, modulo engine attribution, (b) keep the merged ledger
/// balanced and the paged-KV allocator leak-free, and (c) be reproduced
/// bit-for-bit — INCLUDING engine attribution — by replaying its
/// JSON-round-tripped placement log on a fresh service.
#[test]
fn prop_steal_replay_bit_identical() {
    let max_seq = 16usize;
    type Key = (usize, Vec<i32>, Vec<u32>, FinishReason, Option<u32>);
    // ((engines, slots), [(group_size, temp_bit); n])
    let g = Pair(Pair(UsizeIn(2, 3), UsizeIn(1, 3)),
                 VecOf(Pair(UsizeIn(1, 5), UsizeIn(0, 1)), 2, 10));
    assert_prop("steal-replay-parity", 0x57EA1, 60, &g,
                |((engines, slots), groups)| {
        let n_eng = (*engines).max(2);
        let slots = (*slots).max(1);
        let build = || -> RolloutService<MockEngine> {
            let engs: Vec<MockEngine> = (0..n_eng)
                .map(|_| MockEngine::new(slots, 8, max_seq, 2))
                .collect();
            let mut svc = RolloutService::new(engs, max_seq, 2);
            svc.stripe = StripePolicy::LeastLoaded;
            svc.set_kv(KvConfig {
                layout: KvLayout::Paged,
                page_size: 4,
                budget_pages: None,
            });
            svc
        };
        let fingerprint = |svc: &mut RolloutService<MockEngine>|
                          -> Vec<Key> {
            for (gid, &(sz, temp)) in groups.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    // skewed decode budgets: even groups run ~9x longer,
                    // the straggler shape stealing exists for
                    max_new: if gid % 2 == 0 { 9 } else { 1 },
                    temperature: temp as f32,
                    top_p: 1.0,
                    seed: 0x57 ^ ((gid as u64) << 8),
                });
            }
            let results = svc.run(|gid, _| (gid % 2) as f32).unwrap();
            results
                .iter()
                .flat_map(|gr| gr.members.iter().map(move |m| {
                    (gr.engine,
                     m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits())
                         .collect::<Vec<u32>>(),
                     m.result.finish,
                     m.reward.map(|r| r.to_bits()))
                }))
                .collect()
        };
        // the recorded stolen run
        let mut stolen = build();
        stolen.steal = StealPolicy::Idle;
        let fs = fingerprint(&mut stolen);
        let st = stolen.take_stats().unwrap();
        if st.completed + st.cancelled != st.submitted
            || st.kv_pages_freed != st.kv_pages_allocated
        {
            return false; // stealing unbalanced a ledger
        }
        // same outputs with stealing off, modulo engine attribution
        let mut plain = build();
        let fp = fingerprint(&mut plain);
        if fs.len() != fp.len()
            || !fs.iter().zip(&fp).all(|(a, b)| {
                (&a.1, &a.2, a.3, a.4) == (&b.1, &b.2, b.3, b.4)
            })
        {
            return false; // stealing changed completed outputs
        }
        // replay the log (JSON round-tripped) on a fresh service:
        // bit-identical including engine attribution, zero live steals
        let log = PlacementLog::from_json(
            &stolen.placement_log().to_json()).unwrap();
        let mut replayed = build();
        replayed.set_replay(log);
        fingerprint(&mut replayed) == fs
            && replayed.placement_log().steals() == 0
    });
}

/// The same contract across the thread boundary: a THREADED run with work
/// stealing enabled resolves every group, balances the merged ledger,
/// reports exactly as many steals in its drained stats as its placement
/// log records, and an INLINE replay of that log reproduces the completed
/// outputs bit-for-bit including engine attribution (the inline backend
/// is the reference semantics; placement is data, not thread timing).
#[test]
fn prop_threaded_steal_ledger_and_replay() {
    let max_seq = 16usize;
    type Key = (usize, Vec<i32>, Vec<u32>, FinishReason, Option<u32>);
    // (slots, [(group_size, temp_bit); n])
    let g = Pair(UsizeIn(1, 3),
                 VecOf(Pair(UsizeIn(1, 4), UsizeIn(0, 1)), 2, 8));
    assert_prop("threaded-steal-replay", 0x7EA15, 30, &g,
                |(slots, groups)| {
        let slots = (*slots).max(1);
        let n_eng = 3usize;
        let fingerprint = |svc: &mut RolloutService<MockEngine>|
                          -> Vec<Key> {
            for (gid, &(sz, temp)) in groups.iter().enumerate() {
                svc.submit_group(GroupSpec {
                    group_id: gid,
                    prompt: vec![3 + (gid as i32 % 5); 2 + gid % 3],
                    group_size: sz.max(1),
                    max_new: if gid % 2 == 0 { 9 } else { 1 },
                    temperature: temp as f32,
                    top_p: 1.0,
                    seed: 0x7E ^ ((gid as u64) << 8),
                });
            }
            let results = svc.run(|gid, _| (gid % 2) as f32).unwrap();
            results
                .iter()
                .flat_map(|gr| gr.members.iter().map(move |m| {
                    (gr.engine,
                     m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits())
                         .collect::<Vec<u32>>(),
                     m.result.finish,
                     m.reward.map(|r| r.to_bits()))
                }))
                .collect()
        };
        let factories: Vec<EngineFactory<MockEngine>> = (0..n_eng)
            .map(|_| {
                Box::new(move || Ok(MockEngine::new(slots, 8, max_seq, 2)))
                    as EngineFactory<MockEngine>
            })
            .collect();
        let mut svc =
            RolloutService::threaded(factories, max_seq, 2).unwrap();
        svc.stripe = StripePolicy::LeastLoaded;
        svc.steal = StealPolicy::Idle;
        let fs = fingerprint(&mut svc);
        let st = svc.take_stats().unwrap();
        if st.completed != st.submitted {
            return false; // no pruning: every member must complete
        }
        if st.steals != svc.placement_log().steals() {
            return false; // stats and log disagree on steal count
        }
        let log = svc.placement_log().clone();
        let engs: Vec<MockEngine> = (0..n_eng)
            .map(|_| MockEngine::new(slots, 8, max_seq, 2))
            .collect();
        let mut replayed = RolloutService::new(engs, max_seq, 2);
        replayed.set_replay(log);
        fingerprint(&mut replayed) == fs
    });
}

/// Regression property for the trainer's old `padded_g = 1` fallback: on a
/// ragged batch (len % group_size != 0) the grouped-advantage path must
/// preserve per-group zero mean AND emit a nonzero signal whenever a group
/// has reward variance — the singleton fallback zeroed every advantage in
/// the chunk.
#[test]
fn prop_grpo_by_group_ragged() {
    let g = Pair(UsizeIn(2, 6), VecOf(F64In(0.0, 1.0), 2, 40));
    assert_prop("grpo-grouped-ragged", 0xBADC, 500, &g, |(gsize, vals)| {
        let gsize = (*gsize).max(2);
        if vals.len() < 2 {
            return true;
        }
        let rewards: Vec<f32> =
            vals.iter().map(|&v| if v > 0.5 { 1.0 } else { 0.0 }).collect();
        let groups: Vec<usize> = (0..rewards.len()).map(|i| i / gsize).collect();
        let adv = advantage::grpo_by_group(&rewards, &groups);
        // per-group zero mean, including the ragged tail
        let mut start = 0;
        while start < rewards.len() {
            let end = (start + gsize).min(rewards.len());
            let sum: f32 = adv[start..end].iter().sum();
            if sum.abs() > 1e-3 {
                return false;
            }
            let chunk = &rewards[start..end];
            let mixed = chunk.iter().any(|&r| r != chunk[0]);
            let has_signal = adv[start..end].iter().any(|&a| a.abs() > 1e-3);
            if mixed != has_signal {
                return false; // variance <=> nonzero advantages
            }
            start = end;
        }
        true
    });
}

/// GRPO advantages: zero mean within every group; zero for uniform groups;
/// sign matches reward deviation.
#[test]
fn prop_grpo_group_mean_zero() {
    let g = Pair(UsizeIn(2, 8), VecOf(F64In(0.0, 1.0), 2, 8));
    assert_prop("grpo-zero-mean", 0xB22, 500, &g, |(gsize, rewards_f)| {
        let gsize = (*gsize).max(2);
        // build a rewards vector with len = k * gsize
        let k = rewards_f.len().max(1).div_ceil(gsize);
        let rewards: Vec<f32> = (0..k * gsize)
            .map(|i| rewards_f.get(i % rewards_f.len().max(1))
                 .copied()
                 .unwrap_or(0.0)
                 .round() as f32)
            .collect();
        let adv = advantage::grpo(&rewards, gsize);
        for chunk in adv.chunks_exact(gsize) {
            let sum: f32 = chunk.iter().sum();
            if sum.abs() > 1e-3 {
                return false;
            }
        }
        true
    });
}

/// GAE with gamma=lambda=1 telescopes to reward - value.
#[test]
fn prop_gae_telescopes() {
    let g = VecOf(F64In(-1.0, 1.0), 1, 30);
    assert_prop("gae-telescope", 0xC33, 300, &g, |values_f| {
        let values: Vec<f32> = values_f.iter().map(|&v| v as f32).collect();
        let (adv, ret) = advantage::gae(&values, 1.0, 1.0, 1.0);
        for t in 0..values.len() {
            if (adv[t] - (1.0 - values[t])).abs() > 1e-4 {
                return false;
            }
            if (ret[t] - 1.0).abs() > 1e-4 {
                return false;
            }
        }
        true
    });
}

/// ACR's clip window contains TIS's: with positive advantage the ACR
/// surrogate is >= the TIS surrogate; they coincide when rho <= C.
#[test]
fn prop_acr_dominates_tis_positive_adv() {
    let g = VecOf(F64In(-3.0, 3.0), 3, 3);
    assert_prop("acr>=tis", 0xD44, 2000, &g, |v| {
        let (lp_theta, lp_behav, lp_prox) = (v[0] as f32, v[1] as f32, v[2] as f32);
        let mk = |kind| Objective { kind, tis_cap: 2.0, eps_low: 0.2,
                                    eps_high: 0.28, ..Objective::default() };
        let adv = 1.0;
        let tis = surrogate_token(&mk(ObjectiveKind::Tis), lp_theta, lp_behav,
                                  lp_prox, adv);
        let acr = surrogate_token(&mk(ObjectiveKind::Acr), lp_theta, lp_behav,
                                  lp_prox, adv);
        if acr < tis - 1e-5 {
            return false;
        }
        // no truncation -> identical
        let rho = (lp_prox - lp_behav).exp();
        if rho <= 2.0 && (acr - tis).abs() > 1e-5 {
            return false;
        }
        true
    });
}

/// TIS surrogate magnitude is bounded by C x |clip window x adv|, unlike
/// decoupled (the Fig. 3b blow-up).
#[test]
fn prop_tis_bounded() {
    let g = VecOf(F64In(-8.0, 8.0), 3, 3);
    assert_prop("tis-bounded", 0xE55, 2000, &g, |v| {
        let obj = Objective { kind: ObjectiveKind::Tis, tis_cap: 2.0,
                              eps_low: 0.2, eps_high: 0.28,
                              ..Objective::default() };
        let s = surrogate_token(&obj, v[0] as f32, v[1] as f32, v[2] as f32,
                                1.0);
        // ratio clipped to <= 1.28 only on the min side for adv>0;
        // unclipped branch can exceed but the min picks the smaller:
        // bound = C * max(ratio_clip_hi * adv) with ratio <= e^20 clamp...
        // practical bound: C * (1 + eps_high) when clipped branch wins, or
        // C * ratio when ratio < hi; either way <= C * max(hi, ratio<=hi)
        s <= 2.0 * 1.28 + 1e-4
    });
}

/// Dynamic sampling keeps exactly the informative groups.
#[test]
fn prop_dapo_filter_correct() {
    let g = Pair(UsizeIn(2, 6), VecOf(F64In(0.0, 1.0), 4, 48));
    assert_prop("dapo-filter", 0xF66, 500, &g, |(gsize, vals)| {
        let gsize = (*gsize).max(2);
        let n_groups = vals.len() / gsize;
        if n_groups == 0 {
            return true;
        }
        let rewards: Vec<f32> = vals[..n_groups * gsize]
            .iter()
            .map(|&v| if v > 0.5 { 1.0 } else { 0.0 })
            .collect();
        let keep = dapo::informative_groups(&rewards, gsize);
        for g_i in 0..n_groups {
            let chunk = &rewards[g_i * gsize..(g_i + 1) * gsize];
            let uniform = chunk.iter().all(|&r| r == chunk[0]);
            let kept = keep.contains(&g_i);
            if uniform == kept {
                return false;
            }
        }
        true
    });
}

/// Tokenizer round-trip over arbitrary problem strings.
#[test]
fn prop_tokenizer_roundtrip_all_families() {
    let g = Pair(UsizeIn(0, 5), UsizeIn(0, 3));
    let tk = Tokenizer::new();
    assert_prop("tokenizer-roundtrip", 0x1A7, 1500, &g, |(fam_i, diff)| {
        let fam: Family = ALL_FAMILIES[fam_i % ALL_FAMILIES.len()];
        let mut rng = Pcg64::new((fam_i * 131 + diff) as u64);
        let p = fam.sample(&mut rng, *diff);
        let ids = tk.encode(&p.prompt);
        tk.decode(&ids) == p.prompt && {
            let a = tk.encode(&p.answer);
            tk.decode(&a) == p.answer
        }
    });
}

/// Reward verifier: generated answer == reference iff reward is 1.
#[test]
fn prop_verifier_exactness() {
    let g = UsizeIn(0, 10_000);
    assert_prop("verifier-exact", 0x1B8, 800, &g, |seed| {
        let mut rng = Pcg64::new(*seed as u64);
        for fam in ALL_FAMILIES {
            let p = fam.sample(&mut rng, 2);
            if qurl::tasks::verify(&p, &p.answer) != 1.0 {
                return false;
            }
            let wrong = format!("{}9", p.answer);
            if qurl::tasks::verify(&p, &wrong) != 0.0 {
                return false;
            }
        }
        true
    });
}

/// Quantization mirrors: dequantized int8 error bounded by half a step;
/// e4m3 idempotent; both preserve sign.
#[test]
fn prop_quant_bounds() {
    use qurl::quant::{fp8, int8};
    let g = Pair(UsizeIn(1, 64), UsizeIn(0, 10_000));
    assert_prop("quant-bounds", 0x1C9, 200, &g, |(k, seed)| {
        let k = (*k).max(1);
        let n = 8;
        let mut rng = Pcg64::new(*seed as u64);
        let w: Vec<f32> = (0..k * n)
            .map(|_| rng.normal() as f32 * 0.05)
            .collect();
        let (q, s) = int8::weight_quant(&w, k, n);
        let deq = int8::dequant(&q, &s, k, n);
        for i in 0..w.len() {
            if (w[i] - deq[i]).abs() > 0.5 * s[i % n] + 1e-9 {
                return false;
            }
        }
        let fq = fp8::weight_quant(&w, k, n);
        let fq2 = fp8::weight_quant(&fq, k, n);
        for i in 0..w.len() {
            if (fq[i] - fq2[i]).abs() > 1e-6 {
                return false;
            }
            if fq[i] != 0.0 && w[i] != 0.0 && fq[i].signum() != w[i].signum() {
                return false;
            }
        }
        true
    });
}
